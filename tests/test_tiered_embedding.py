"""Tiered embedding runtime + planner placement execution.

Covers the PR's correctness contract:
  * planner placement edge cases (oversized table, total overflow,
    zero-frequency tables);
  * tiered lookup == `embedding_bag_ref` on a Zipf-skewed stream (both the
    dual-array Pallas path and the packed single-gather path);
  * training integration (tier-routed row updates + LFU refresh);
  * the plan-driven distributed serve/train steps consume the placements
    and still match the single-device reference (subprocess, 8 devices).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_dlrm
from repro.core import tiered_embedding as te
from repro.core.planner import TablePlacement, place_tables, plan_with_placement
from repro.data.recsys import make_recsys_batch
from repro.kernels import ref


# ------------------------------------------------------------- planner edges
def _cfg(T=8):
    return dataclasses.replace(get_dlrm("dlrm-rm2-small-unsharded").reduced(),
                               num_tables=T)


def test_place_tables_oversized_table_goes_bulk():
    cfg = _cfg()
    tbytes = cfg.rows_per_table * cfg.embed_dim * 2
    freq = np.ones(cfg.num_tables)
    # fast tier smaller than one table: nothing can be fast
    placements, fast_used, bulk_used = place_tables(
        cfg, freq, fast_capacity_bytes=tbytes - 1,
        bulk_capacity_bytes=tbytes * cfg.num_tables, n_chips=2)
    assert all(p.tier == "bulk" for p in placements)
    assert fast_used == 0 and bulk_used == tbytes * cfg.num_tables


def test_place_tables_total_overflow_raises_naming_table():
    cfg = _cfg()
    tbytes = cfg.rows_per_table * cfg.embed_dim * 2
    with pytest.raises(ValueError, match=r"table \d+"):
        place_tables(cfg, np.ones(cfg.num_tables),
                     fast_capacity_bytes=0,
                     bulk_capacity_bytes=tbytes * 2,  # 4 chip-tables < 8
                     n_chips=2)


def test_place_tables_zero_frequency_tables():
    cfg = _cfg()
    tbytes = cfg.rows_per_table * cfg.embed_dim * 2
    placements, _, _ = place_tables(
        cfg, np.zeros(cfg.num_tables), fast_capacity_bytes=2 * tbytes,
        bulk_capacity_bytes=tbytes * cfg.num_tables, n_chips=2)
    # every table placed exactly once, no crash on 0-density
    assert sorted(p.table_id for p in placements) == list(range(cfg.num_tables))


def test_plan_hit_ratio_tracks_fast_mass():
    from repro.core.perf_model import recspeed_system
    cfg = _cfg()
    sys_ = dataclasses.replace(recspeed_system(), n_chips=2)
    tbytes = cfg.rows_per_table * cfg.embed_dim * 2
    freq = np.arange(1.0, cfg.num_tables + 1)
    plan = plan_with_placement(cfg, sys_, freq, fast_capacity_bytes=2 * tbytes,
                               bulk_capacity_bytes=tbytes * cfg.num_tables)
    fast_ids = [p.table_id for p in plan.placements if p.tier == "fast"]
    assert len(fast_ids) == 4
    np.testing.assert_allclose(plan.hit_ratio,
                               freq[fast_ids].sum() / freq.sum())


def test_reconcile_plan_with_mesh_matches_execution():
    """plan.hit_ratio must describe the EXECUTED placement: when the mesh
    demotes spill fast tables (len(fast) % n != 0), reconciliation folds the
    demotion back into placements + hit ratio."""
    from repro.core import sharding as dsh
    from repro.core.perf_model import recspeed_system

    cfg = _cfg()
    tbytes = cfg.rows_per_table * cfg.embed_dim * 2
    freq = np.arange(1.0, cfg.num_tables + 1)
    sys3 = dataclasses.replace(recspeed_system(), n_chips=3)
    plan = plan_with_placement(cfg, sys3, freq, tbytes,
                               tbytes * cfg.num_tables)  # 3 fast tables
    assert sum(1 for p in plan.placements if p.tier == "fast") == 3
    rec = dsh.reconcile_plan_with_mesh(plan, 4, freq)    # 3 % 4 -> all demoted
    assert sum(1 for p in rec.placements if p.tier == "fast") == 0
    assert rec.hit_ratio == 0.0
    # groups derived from the reconciled plan agree with the original ones
    assert dsh.plan_table_groups(rec, 4) == dsh.plan_table_groups(plan, 4)
    # divisible mesh: reconciliation is the identity
    rec3 = dsh.reconcile_plan_with_mesh(plan, 3, freq)
    assert rec3.placements == plan.placements
    np.testing.assert_allclose(rec3.hit_ratio, plan.hit_ratio)
    # with freq in hand the spill demotes the COLDEST fast table, not the
    # highest id: 3 fast {5,6,7} (freq ascending), n=2 -> demote table 5
    rec2 = dsh.reconcile_plan_with_mesh(plan, 2, freq)
    fast2 = {p.table_id for p in rec2.placements if p.tier == "fast"}
    assert fast2 == {6, 7}
    np.testing.assert_allclose(rec2.hit_ratio,
                               freq[[6, 7]].sum() / freq.sum())


# ------------------------------------------------- tiered lookup correctness
@pytest.mark.parametrize("alpha", [0.0, 1.05])
@pytest.mark.parametrize("hot", [0, 3, 16])
def test_tiered_lookup_matches_ref(alpha, hot):
    cfg = _cfg()
    tables = jax.random.normal(
        jax.random.PRNGKey(1),
        (cfg.num_tables, cfg.rows_per_table, cfg.embed_dim))
    freq = te.measure_row_freq(cfg, alpha=alpha, n_batches=3)
    tiered = te.build_tiered_tables(tables, freq, hot)
    b = make_recsys_batch(cfg, 11, 0, alpha)
    expect = ref.embedding_bag_ref(tables, b["indices"])
    # dual-array (Pallas cached-bag) path
    np.testing.assert_allclose(te.tiered_embedding_bag(tiered, b["indices"]),
                               expect, rtol=1e-5, atol=1e-5)
    # packed single-gather path (existing scalar-prefetch kernel)
    packed = te.packed_tables(tiered)
    np.testing.assert_allclose(
        te.tiered_embedding_bag_packed(packed, tiered, b["indices"]),
        expect, rtol=1e-5, atol=1e-5)


def test_tiered_lookup_with_placements_matches_ref():
    cfg = _cfg()
    tables = jax.random.normal(
        jax.random.PRNGKey(2),
        (cfg.num_tables, cfg.rows_per_table, cfg.embed_dim))
    freq = te.measure_row_freq(cfg, alpha=1.05, n_batches=3)
    placements = ([TablePlacement(0, "fast", "table_wise", 0)] +
                  [TablePlacement(t, "bulk", "row_wise", None)
                   for t in range(1, cfg.num_tables)])
    tiered = te.build_tiered_tables(tables, freq, 8, placements)
    # fast-placed table fully resident: every row hot
    assert int((np.asarray(tiered.row_map[0]) >= 0).sum()) == cfg.rows_per_table
    b = make_recsys_batch(cfg, 5, 0, 1.05)
    np.testing.assert_allclose(te.tiered_embedding_bag(tiered, b["indices"]),
                               ref.embedding_bag_ref(tables, b["indices"]),
                               rtol=1e-5, atol=1e-5)


def test_expected_hit_ratio_grows_with_skew_and_budget():
    cfg = _cfg()
    f_uni = te.measure_row_freq(cfg, alpha=0.0, n_batches=3)
    f_skew = te.measure_row_freq(cfg, alpha=1.2, n_batches=3)
    tables = jnp.zeros((cfg.num_tables, cfg.rows_per_table, cfg.embed_dim))
    t_uni = te.build_tiered_tables(tables, f_uni, 8)
    t_skew = te.build_tiered_tables(tables, f_skew, 8)
    t_skew_big = te.build_tiered_tables(tables, f_skew, 32)
    h_uni = te.expected_hit_ratio(f_uni, t_uni)
    h_skew = te.expected_hit_ratio(f_skew, t_skew)
    h_big = te.expected_hit_ratio(f_skew, t_skew_big)
    assert h_skew > h_uni
    assert h_big > h_skew


# ------------------------------------------------------ training integration
def test_tiered_row_update_and_refresh_match_dense_sgd():
    """Tier-routed sparse SGD + LFU refresh == dense scatter-add update."""
    cfg = _cfg(T=4)
    key = jax.random.PRNGKey(3)
    tables = jax.random.normal(
        key, (cfg.num_tables, cfg.rows_per_table, cfg.embed_dim))
    freq = te.measure_row_freq(cfg, alpha=1.05, n_batches=2)
    tiered = te.build_tiered_tables(tables, freq, 8)

    b = make_recsys_batch(cfg, 0, 0, 1.05)
    idx = b["indices"]
    B, T, L = idx.shape
    g_rows = jax.random.normal(key, (B, T, L, cfg.embed_dim))
    lr = 0.1

    tiered2 = te.tiered_row_update(tiered, idx, g_rows, lr)
    # dense reference update
    expect = tables
    flat_idx = idx.transpose(1, 0, 2).reshape(T, B * L)
    flat_g = g_rows.transpose(1, 0, 2, 3).reshape(T, B * L, -1)
    expect = jax.vmap(lambda t, i, g: t.at[i].add(-lr * g))(
        expect, flat_idx, flat_g)

    # lookups through the updated tiered store see the updated rows
    b2 = make_recsys_batch(cfg, 1, 0, 1.05)
    np.testing.assert_allclose(
        te.tiered_embedding_bag(tiered2, b2["indices"]),
        ref.embedding_bag_ref(expect, b2["indices"]), rtol=1e-4, atol=1e-4)
    # LFU refresh flushes hot rows back and preserves semantics
    tiered3 = te.lfu_refresh(tiered2, freq + 1)
    np.testing.assert_allclose(np.asarray(te.flush_to_bulk(tiered3)),
                               np.asarray(expect), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(
        te.tiered_embedding_bag(tiered3, b2["indices"]),
        ref.embedding_bag_ref(expect, b2["indices"]), rtol=1e-4, atol=1e-4)


def test_lfu_refresh_preserves_mixed_placement_shape():
    """Regression: refreshing a mixed store (one fully-fast table + row
    caches) with default args must NOT inflate every table to fully hot."""
    cfg = _cfg(T=4)
    tables = jax.random.normal(
        jax.random.PRNGKey(5),
        (cfg.num_tables, cfg.rows_per_table, cfg.embed_dim))
    freq = te.measure_row_freq(cfg, alpha=1.05, n_batches=2)
    placements = ([TablePlacement(0, "fast", "table_wise", 0)] +
                  [TablePlacement(t, "bulk", "row_wise", None)
                   for t in range(1, cfg.num_tables)])
    tiered = te.build_tiered_tables(tables, freq, 8, placements)
    refreshed = te.lfu_refresh(tiered, freq + 1)
    counts = (np.asarray(refreshed.row_map) >= 0).sum(axis=1)
    assert counts[0] == cfg.rows_per_table          # still fully resident
    assert (counts[1:] == 8).all()                  # caches stayed 8 rows
    b = make_recsys_batch(cfg, 2, 0, 1.05)
    np.testing.assert_allclose(te.tiered_embedding_bag(refreshed, b["indices"]),
                               ref.embedding_bag_ref(tables, b["indices"]),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------- plan-driven distributed steps (8 dev)
PLANNED_CASE = """
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro.configs.registry import get_dlrm
from repro.core import dlrm as dlrm_lib
from repro.core import sharding as dsh
from repro.core.planner import plan_with_placement
from repro.core.perf_model import recspeed_system
from repro.data import make_recsys_batch
from repro.launch.mesh import make_mesh

cfg = get_dlrm("dlrm-rm2-small-sharded").reduced()
cfg = dataclasses.replace(cfg, batch_size=32, rows_per_table=128, num_tables=8)
mesh = make_mesh((2, 4), ("data", "model"))
sys_ = dataclasses.replace(recspeed_system(), n_chips=4)
tbytes = cfg.rows_per_table * cfg.embed_dim * 2
freq = np.linspace(1.0, 8.0, cfg.num_tables)
plan = plan_with_placement(cfg, sys_, freq, fast_capacity_bytes=tbytes,
                           bulk_capacity_bytes=tbytes * 8)
groups = dsh.plan_table_groups(plan, 4)
assert groups.fast_ids and groups.bulk_ids, groups   # genuinely MIXED

params = dlrm_lib.init_dlrm(jax.random.PRNGKey(0), cfg)
b0 = make_recsys_batch(cfg, 0)

serve = dsh.make_dlrm_serve_step(cfg, mesh, "model", "partial_pool",
                                 dp_axes=("data",), plan=plan)
sp = dsh.shard_dlrm_params(params, cfg, mesh, "model", plan=plan)
probs = jax.device_get(serve(sp, b0["dense"], b0["indices"]))
expect = jax.device_get(dlrm_lib.predict(params, b0["dense"], b0["indices"], cfg))
np.testing.assert_allclose(probs, expect, rtol=2e-5, atol=2e-6)

step = dsh.make_dlrm_train_step(cfg, mesh, "model", lr=0.05, optimizer="sgd",
                                dp_axes=("data",), plan=plan)
sp = dsh.shard_dlrm_params(params, cfg, mesh, "model", plan=plan)
opt = dsh.init_dlrm_opt_state(cfg, "sgd", plan, 4)
ref_params = jax.tree_util.tree_map(lambda x: x.copy(), params)
for s in range(3):
    b = make_recsys_batch(cfg, s)
    sp, opt, loss = step(sp, opt, b["dense"], b["indices"], b["labels"])
    ref_params, _ = dlrm_lib.reference_train_step(
        ref_params, b["dense"], b["indices"], b["labels"], cfg, 0.05)
merged = dsh.merge_dlrm_params_by_plan(jax.device_get(sp), groups)
for k in ("bot_mlp", "top_mlp", "tables"):
    for x, y in zip(jax.tree_util.tree_leaves(merged[k]),
                    jax.tree_util.tree_leaves(jax.device_get(ref_params[k]))):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=2e-5, atol=2e-5, err_msg=k)

params2 = dlrm_lib.init_dlrm(jax.random.PRNGKey(1), cfg)
step = dsh.make_dlrm_train_step(cfg, mesh, "model", lr=0.05,
                                optimizer="adagrad", dp_axes=("data",),
                                plan=plan)
sp = dsh.shard_dlrm_params(params2, cfg, mesh, "model", plan=plan)
opt = dsh.init_dlrm_opt_state(cfg, "adagrad", plan, 4)
sp, opt, loss = step(sp, opt, b0["dense"], b0["indices"], b0["labels"])
assert np.isfinite(float(loss))
print("MATCH")
"""


def test_planned_steps_execute_placements(subproc):
    r = subproc(PLANNED_CASE)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MATCH" in r.stdout
