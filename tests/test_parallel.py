"""repro.parallel stage layer: the refactor's contract.

  * pipelined step == serial step (allclose) for depth in {1,2,4}, across
    exchange modes (table_wise pooled a2a, row_wise partial_pool/unpooled,
    planned tiered) and plan none/auto, on an 8-virtual-device CPU mesh —
    train (sgd + adagrad) and serve;
  * compressed-grad training (int8 + error feedback) still decreases loss
    and carries live EF state;
  * the legacy `core.sharding` import paths (make_dlrm_train_step /
    make_dlrm_serve_step and friends) still resolve, and the module stayed
    a thin shim;
  * the engine resolves/clamps pipeline depth, and auto-plan reports carry
    the planner-chosen depth;
  * the pipeline bench is registered in benchmarks/run.py.
"""
import dataclasses
import os

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

PIPE_CASE = """
import jax, jax.numpy as jnp, numpy as np, dataclasses
assert len(jax.devices()) == 8, jax.devices()
from repro.configs.registry import get_dlrm
from repro.core import dlrm as dlrm_lib
from repro.data import make_recsys_batch
from repro.launch.mesh import make_mesh
from repro.parallel import build_step, shard_dlrm_params, init_dlrm_opt_state

cfg = get_dlrm("{config}").reduced()
cfg = dataclasses.replace(cfg, batch_size=32, rows_per_table=128, num_tables=8)
mesh = make_mesh((2, 4), ("data", "model"))
alpha = 1.05 if "{plan}" == "auto" else 0.0

plan = None
if "{plan}" == "auto":
    from repro.engine import Engine
    plan = Engine(cfg, mesh=mesh, plan="auto", alpha=alpha).build_plan("training")
    assert plan is not None and plan.placements

params_host = jax.device_get(dlrm_lib.init_dlrm(jax.random.PRNGKey(0), cfg))
def fresh():
    return jax.tree_util.tree_map(np.copy, params_host)

# -- train: depth 1/2/4 produce the same params after 2 steps --
outs = {{}}
for depth in (1, 2, 4):
    p = shard_dlrm_params(fresh(), cfg, mesh, ("data", "model"), plan=plan)
    o = init_dlrm_opt_state(cfg, "{optimizer}", plan, 8)
    step = build_step(cfg, mesh, mode="train", plan=plan,
                      exchange="{exchange}", optimizer="{optimizer}",
                      lr=0.05, pipeline_depth=depth)
    for s in range(2):
        b = make_recsys_batch(cfg, s, 0, alpha)
        p, o, loss = step(p, o, b["dense"], b["indices"], b["labels"])
    outs[depth] = (jax.device_get(p), float(loss))
for depth in (2, 4):
    for x, y in zip(jax.tree_util.tree_leaves(outs[1][0]),
                    jax.tree_util.tree_leaves(outs[depth][0])):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=3e-5, atol=3e-5,
                                   err_msg=f"train depth={{depth}}")
    assert abs(outs[1][1] - outs[depth][1]) < 1e-4

# -- serve: pipelined probs == serial probs == single-device reference --
b = make_recsys_batch(cfg, 0, 0, alpha)
sp = shard_dlrm_params(fresh(), cfg, mesh, ("data", "model"), plan=plan)
ref = jax.device_get(dlrm_lib.predict(fresh(), b["dense"], b["indices"], cfg))
for depth in (1, 2, 4):
    serve = build_step(cfg, mesh, mode="serve", plan=plan,
                       exchange="{exchange}", pipeline_depth=depth)
    probs = jax.device_get(serve(sp, b["dense"], b["indices"]))
    np.testing.assert_allclose(probs, ref, rtol=2e-5, atol=2e-6,
                               err_msg=f"serve depth={{depth}}")
print("MATCH")
"""


@pytest.mark.parametrize("config,exchange,optimizer,plan", [
    ("dlrm-rm2-small-unsharded", "partial_pool", "sgd", "none"),
    ("dlrm-rm2-small-sharded", "partial_pool", "adagrad", "none"),
    ("dlrm-rm2-small-sharded", "unpooled", "sgd", "none"),
    ("dlrm-rm2-small-unsharded", "partial_pool", "adagrad", "auto"),
])
def test_pipelined_step_matches_serial(subproc, config, exchange, optimizer,
                                       plan):
    r = subproc(PIPE_CASE.format(config=config, exchange=exchange,
                                 optimizer=optimizer, plan=plan))
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MATCH" in r.stdout


INDIVISIBLE_CASE = """
import jax, dataclasses
from repro.configs.registry import get_dlrm
from repro.core import dlrm as dlrm_lib
from repro.data import make_recsys_batch
from repro.launch.mesh import make_mesh
from repro.parallel import build_step, shard_dlrm_params

cfg = get_dlrm("dlrm-rm2-small-unsharded").reduced()
cfg = dataclasses.replace(cfg, batch_size=24, rows_per_table=128, num_tables=8)
mesh = make_mesh((8,), ("x",))
serve = build_step(cfg, mesh, mode="serve", axis="x", pipeline_depth=2)
sp = shard_dlrm_params(dlrm_lib.init_dlrm(jax.random.PRNGKey(0), cfg),
                       cfg, mesh, "x")
b = make_recsys_batch(cfg, 0)
try:
    serve(sp, b["dense"], b["indices"])
    print("NO-ERROR")
except ValueError as e:
    assert "pipeline_depth" in str(e), e
    print("RAISED")
"""


def test_indivisible_micro_batch_raises(subproc):
    """24 samples / 8 devices = 3 per device: depth 2 must refuse."""
    r = subproc(INDIVISIBLE_CASE)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "RAISED" in r.stdout


def _cfg():
    from repro.configs.registry import get_dlrm
    cfg = get_dlrm("dlrm-rm2-small-unsharded").reduced()
    return dataclasses.replace(cfg, batch_size=8)


def test_compressed_grads_training_decreases_loss():
    """int8 + error-feedback dense all-reduce must not break learning, and
    the EF residual state must be live (non-zero after steps)."""
    import jax
    from repro.engine import Engine
    # the planted teacher carries most of its signal in the embedding rows
    # (data/recsys.py SPARSE_SIGNAL) which SGD learns row-by-row — descent
    # needs a real batch and enough steps to clear the per-batch noise
    cfg = dataclasses.replace(_cfg(), batch_size=128)
    eng = Engine(cfg, lr=1.0, compress_grads=True)
    sess = eng.train_session()
    rep = sess.run(100)
    losses = [h["loss"] for h in rep.history]
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.02, losses
    ef_leaves = [np.asarray(x) for x in jax.tree_util.tree_leaves(
        jax.device_get(sess.opt_state["ef"]))]
    assert max(float(np.abs(e).max()) for e in ef_leaves) > 0.0


def test_compressed_pipelined_matches_uncompressed_closely():
    """Compression is near-transparent: int8 block quantization with EF
    tracks the uncompressed trajectory to ~1e-3 over a few steps."""
    from repro.engine import Engine
    losses = {}
    for compress in (False, True):
        eng = Engine(_cfg(), lr=0.05, compress_grads=compress,
                     pipeline_depth=2)
        rep = eng.train_session().run(5)
        losses[compress] = [h["loss"] for h in rep.history]
    np.testing.assert_allclose(losses[True], losses[False], atol=5e-3)


def test_legacy_sharding_import_paths_resolve():
    from repro.core.sharding import (                       # noqa: F401
        make_dlrm_train_step, make_dlrm_serve_step, param_specs,
        shard_dlrm_params, init_dlrm_opt_state, plan_table_groups,
        reconcile_plan_with_mesh, split_dlrm_params_by_plan,
        merge_dlrm_params_by_plan, row_wise_forward, table_wise_forward,
        adagrad_row_update, sgd_row_update, PlanGroups)
    import repro.core.sharding as mod
    import repro.parallel as par
    # the monolith is gone: a thin shim delegating to repro.parallel
    with open(mod.__file__) as f:
        n_lines = len(f.readlines())
    assert n_lines < 200, f"core/sharding.py should be a shim, {n_lines} lines"
    assert mod.plan_table_groups is par.plan_table_groups


def test_engine_resolves_and_clamps_depth():
    from repro.engine import Engine
    cfg = _cfg()                       # 8-sample queries on 1 device
    # explicit depth beyond divisibility is clamped to a feasible one
    eng = Engine(cfg, pipeline_depth=3)
    sess = eng.serve_session(max_batch_queries=1)
    assert sess.pipeline_depth in (1, 2, 4, 8)
    assert (sess.max_batch_queries * sess.query_size) % \
        (eng.n_devices * sess.pipeline_depth) == 0
    # pipelined serving returns the same probabilities
    from repro.data import make_recsys_batch
    b = make_recsys_batch(cfg, 0)
    q = {"dense": b["dense"], "indices": b["indices"]}
    fut = sess.submit(q, now=0.0)
    assert fut.done
    ref = Engine(cfg).serve_session(max_batch_queries=1).serve_direct(
        q["dense"], q["indices"])
    np.testing.assert_allclose(fut.probs, ref, rtol=1e-5, atol=1e-6)
    with pytest.raises(ValueError, match="pipeline_depth"):
        Engine(cfg, pipeline_depth=0)


def test_auto_plan_reports_pipeline_depth():
    from repro.engine import Engine
    eng = Engine(_cfg(), plan="auto", alpha=1.05)
    eng.build_plan("inference")
    rep = eng.plan_report("inference")
    assert rep is not None
    assert rep.pipeline_depth >= 1
    assert rep.depth_sweep and 1 in rep.depth_sweep
    assert rep.depth_sweep[rep.pipeline_depth] == min(
        rep.depth_sweep.values())
    assert f"pipeline_depth={rep.pipeline_depth}" in rep.summary()


def test_pipeline_bench_registered():
    import sys
    if REPO not in sys.path:
        sys.path.insert(0, REPO)
    from benchmarks.run import SECTIONS
    assert "pipeline" in [n for n, _ in SECTIONS]


def test_pipelined_model_beats_serial_somewhere():
    """The executed-schedule perf model must show a depth>1 win in the
    latency-amortized regime (the bench's headline claim)."""
    from repro.configs.registry import get_dlrm
    from repro.core import perf_model
    cfg = dataclasses.replace(get_dlrm("dlrm-rm2-small-sharded"),
                              batch_size=4096)
    sys_cfg = perf_model.recspeed_system()
    best, sweep = perf_model.optimal_pipeline_depth(
        cfg, sys_cfg, "training", row_wise_exchange="partial_pool")
    assert best > 1, sweep
    bd = perf_model.pipelined_breakdown(cfg, sys_cfg, "training",
                                        pipeline_depth=best,
                                        row_wise_exchange="partial_pool")
    assert bd.notes["pipeline_overlap"] > 0.0
    # depth=1 reproduces the serial schedule: zero overlap
    bd1 = perf_model.pipelined_breakdown(cfg, sys_cfg, "training",
                                         pipeline_depth=1,
                                         row_wise_exchange="partial_pool")
    assert bd1.notes["pipeline_overlap"] == 0.0
    assert bd.t_step < bd1.t_step
