"""Engine session API: the config->plan->build->run pipeline behind one
door. CPU-mesh smoke coverage:

  * ServeSession.submit through the micro-batcher == the direct serve step
    (plan=none AND plan=auto — batching must not change results);
  * deadline flush fires on a short queue (injected clock, no sleeping);
  * open-loop driver produces a full latency distribution;
  * TrainSession decreases loss, and checkpoint-resume round-trips to the
    exact state of an uninterrupted run;
  * plan="auto" builds the same reconciled placements/groups as composing
    the pipeline stages by hand;
  * benchmarks/run.py --only rejects unknown sections.
"""
import dataclasses
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs.registry import get_dlrm
from repro.data import make_recsys_batch
from repro.engine import Engine

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _cfg():
    cfg = get_dlrm("dlrm-rm2-small-unsharded").reduced()
    return dataclasses.replace(cfg, batch_size=8)


def _query(cfg, step, alpha=0.0):
    b = make_recsys_batch(cfg, step, 0, alpha)
    return {"dense": b["dense"], "indices": b["indices"]}


@pytest.mark.parametrize("plan", ["none", "auto"])
def test_submit_matches_direct_serve(plan):
    cfg = _cfg()
    eng = Engine(cfg, plan=plan, alpha=1.05)
    sess = eng.serve_session(max_batch_queries=4, max_wait_ms=1e6)
    queries = [_query(cfg, s, alpha=1.05) for s in range(4)]
    futs = [sess.submit(q, now=0.0) for q in queries]
    assert all(f.done for f in futs), "4th submit must flush a full batch"
    for q, fut in zip(queries, futs):
        direct = sess.serve_direct(q["dense"], q["indices"])
        np.testing.assert_allclose(fut.probs, direct, rtol=1e-5, atol=1e-6,
                                   err_msg=f"plan={plan}")


def test_partial_batch_flush_matches_direct():
    """A deadline/forced flush pads the batch; results must still match."""
    cfg = _cfg()
    sess = Engine(cfg).serve_session(max_batch_queries=4, max_wait_ms=1e6)
    q = _query(cfg, 7)
    fut = sess.submit(q, now=0.0)
    assert not fut.done and sess.pending == 1
    sess.flush(now=1.0)
    assert fut.done
    np.testing.assert_allclose(fut.probs,
                               sess.serve_direct(q["dense"], q["indices"]),
                               rtol=1e-5, atol=1e-6)


def test_over_capacity_batch_rejected():
    cfg = _cfg()
    sess = Engine(cfg).serve_session(max_batch_queries=2)
    with pytest.raises(ValueError, match="exceed the micro-batch capacity"):
        sess.measure_service_time(n_queries=3)


def test_deadline_flush_fires_on_short_queue():
    cfg = _cfg()
    sess = Engine(cfg).serve_session(max_batch_queries=8, max_wait_ms=50.0)
    futs = [sess.submit(_query(cfg, s), now=0.0) for s in range(2)]
    assert not any(f.done for f in futs)
    assert not sess.poll(now=0.010)          # before the deadline: no flush
    assert not any(f.done for f in futs)
    assert sess.poll(now=0.051)              # past 50ms: deadline flush
    assert all(f.done for f in futs)
    assert sess.pending == 0
    # a submit that ARRIVES past the oldest query's deadline also flushes
    f1 = sess.submit(_query(cfg, 5), now=1.0)
    f2 = sess.submit(_query(cfg, 6), now=1.2)
    assert f1.done and f2.done


def test_open_loop_reports_full_distribution():
    cfg = _cfg()
    sess = Engine(cfg).serve_session(max_batch_queries=4, max_wait_ms=2.0)
    rep = sess.run_open_loop(20, qps=500.0, sla_ms=1e6)
    assert rep.n_queries == 20
    assert rep.achieved_qps > 0
    assert rep.p50_ms <= rep.p90_ms <= rep.p99_ms
    assert rep.ok and rep.mode == "open_loop"
    # batching must actually have occurred at this rate/capacity
    assert rep.mean_batch_queries > 1.0


def test_train_session_loss_decreases(tmp_path):
    # the planted teacher carries most of its signal in the embedding
    # rows (data/recsys.py SPARSE_SIGNAL), which SGD only learns
    # row-by-row — descent needs a real batch size and enough steps to
    # clear the noise floor, not the 20-step dense-only warmup that
    # sufficed when the teacher was nearly pure-dense
    cfg = dataclasses.replace(_cfg(), batch_size=128)
    eng = Engine(cfg, lr=1.0)
    sess = eng.train_session(ckpt_dir=str(tmp_path), ckpt_every=50)
    rep = sess.run(100)
    assert rep.steps_run == 100
    losses = [h["loss"] for h in rep.history]
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.02, losses


@pytest.mark.parametrize("plan,optimizer", [("none", "sgd"),
                                            ("auto", "adagrad")])
def test_train_resume_roundtrip(tmp_path, plan, optimizer):
    """ckpt at step 4, resume, run 4 more == uninterrupted 8-step run."""
    cfg = _cfg()
    kw = dict(plan=plan, optimizer=optimizer, lr=0.05, alpha=1.05)
    s1 = Engine(cfg, **kw).train_session(ckpt_dir=str(tmp_path), ckpt_every=4)
    s1.run(4)  # TrainLoop.run waits on the async checkpoint writer

    s2 = Engine(cfg, **kw).train_session(ckpt_dir=str(tmp_path), ckpt_every=4)
    assert s2.resume_step == 4
    rep2 = s2.run(4)
    assert rep2.start_step == 4

    straight = Engine(cfg, **kw).train_session()
    straight.run(8)
    resumed_leaves = [np.asarray(x) for x in
                      jax.tree_util.tree_leaves(s2.params)]
    straight_leaves = [np.asarray(x) for x in
                       jax.tree_util.tree_leaves(straight.params)]
    for a, b in zip(resumed_leaves, straight_leaves):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_trained_params_handoff_to_serve():
    """TrainSession.params (plan-split under plan=auto) feed serve_session
    of the same engine; split params without a plan are rejected."""
    cfg = _cfg()
    eng = Engine(cfg, plan="auto", alpha=1.05, lr=0.05)
    train = eng.train_session()
    train.run(3)
    sess = eng.serve_session(max_batch_queries=2, params=train.params)
    q = _query(cfg, 0, alpha=1.05)
    fut = sess.submit(q, now=0.0)
    sess.flush(now=0.0)
    np.testing.assert_allclose(fut.probs,
                               sess.serve_direct(q["dense"], q["indices"]),
                               rtol=1e-5, atol=1e-6)
    with pytest.raises(ValueError, match="no placed plan"):
        Engine(cfg).serve_session(params=train.params)


def test_auto_plan_matches_hand_built_pipeline():
    """Engine's planning stage == composing the stages by hand (the move
    of build_auto_plan out of launch/serve.py changed no decisions)."""
    from repro.core import perf_model, planner, sharding as dsh
    from repro.core import tiered_embedding as te

    cfg = _cfg()
    eng = Engine(cfg, plan="auto", alpha=1.05)
    plan = eng.build_plan("inference")
    assert plan is not None and plan.placements
    rep = eng.plan_report("inference")
    assert rep is not None and rep.predicted_qps > 0

    n = eng.n_devices
    counts = te.measure_row_freq(cfg, 1.05, 0, n_batches=4)
    table_freq = np.asarray(counts.sum(axis=1), dtype=np.float64)
    tbytes = cfg.rows_per_table * cfg.embed_dim * 2
    fast_bytes = -(-(cfg.num_tables // 2) // n) * tbytes
    system = dataclasses.replace(perf_model.recspeed_system(), n_chips=n)
    manual = planner.plan_with_placement(
        cfg, system, table_freq, fast_bytes,
        bulk_capacity_bytes=cfg.num_tables * tbytes, mode="inference")
    manual = dsh.reconcile_plan_with_mesh(manual, n, table_freq)

    assert plan.placements == manual.placements
    assert plan.hit_ratio == pytest.approx(manual.hit_ratio)
    assert (dsh.plan_table_groups(plan, n)
            == dsh.plan_table_groups(manual, n))


def test_launchers_have_no_cross_import():
    """train.py must not import from serve.py (the seed's cross-import)."""
    import repro.launch.serve as serve_mod
    with open(os.path.join(REPO, "src", "repro", "launch", "train.py")) as f:
        src = f.read()
    assert "from repro.launch.serve" not in src
    assert "import serve" not in src
    assert not hasattr(serve_mod, "build_auto_plan")


def test_submit_validates_query_against_cfg():
    """Malformed queries fail at submit time with a clear ValueError, not
    deep inside the jitted step."""
    import jax.numpy as jnp

    cfg = _cfg()
    sess = Engine(cfg).serve_session(max_batch_queries=2)
    good = _query(cfg, 0)
    with pytest.raises(ValueError, match="missing the 'indices'"):
        sess.submit({"dense": good["dense"]})
    with pytest.raises(ValueError, match=r"'dense' must have shape"):
        sess.submit({"dense": good["dense"][:4], "indices": good["indices"]})
    with pytest.raises(ValueError, match=r"'indices' must have shape"):
        sess.submit({"dense": good["dense"],
                     "indices": good["indices"][:, :3]})
    with pytest.raises(ValueError, match="must be floating point"):
        sess.submit({"dense": good["dense"].astype(jnp.int32),
                     "indices": good["indices"]})
    with pytest.raises(ValueError, match="must be an integer dtype"):
        sess.submit({"dense": good["dense"],
                     "indices": good["indices"].astype(jnp.float32)})
    assert sess.pending == 0                   # nothing malformed enqueued
    fut = sess.submit(good, now=0.0)           # a good query still works
    sess.flush(now=0.0)
    assert fut.done


def test_serve_depth_resolved_per_compiled_shape():
    """pipeline_depth=None resolves the planner depth PER compiled batch
    shape (the deadline-flush shape can pick a different depth than the
    capacity shape), and every shape still serves reference results."""
    from repro.engine.planning import resolve_depth_for_batch

    cfg = _cfg()
    eng = Engine(cfg)                          # pipeline_depth=None
    sess = eng.serve_session(max_batch_queries=4, max_wait_ms=1e6)
    assert sess.pipeline_depth is None
    r_full = sess.run_serial(2)                # 8-sample shape
    futs = [sess.submit(_query(cfg, s), now=0.0) for s in range(4)]
    assert all(f.done for f in futs)           # 32-sample capacity shape
    assert set(sess._depth_by_samples) == {8, 32}
    for b, depth in sess._depth_by_samples.items():
        best, sweep = resolve_depth_for_batch(cfg, eng.n_devices, b,
                                              mode="inference",
                                              exchange="partial_pool")
        local = b // eng.n_devices
        want = min(best, local)
        while want > 1 and local % want:
            want -= 1
        assert depth == want, (b, depth, best)
        assert sweep[best] == min(sweep.values())
    # fixed-depth session agrees with the adaptive one
    ref = Engine(cfg, pipeline_depth=1).serve_session(max_batch_queries=4)
    q = _query(cfg, 0)
    np.testing.assert_allclose(futs[0].probs,
                               ref.serve_direct(q["dense"], q["indices"]),
                               rtol=1e-5, atol=1e-6)
    assert r_full.n_queries == 2


def test_engine_dp_axes_validation():
    from repro.configs.registry import get_arch

    cfg = _cfg()
    with pytest.raises(ValueError, match="not in mesh"):
        Engine(cfg, dp_axes=("replica",))
    with pytest.raises(ValueError, match="overlap the"):
        Engine(cfg, dp_axes=("data",))
    with pytest.raises(ValueError, match="DLRM-only"):
        Engine(get_arch("deepseek-7b").reduced(), dp_axes=("data",))


def test_engine_dp_axes_replicated_serving_and_training(subproc):
    """Engine(dp_axes=...) runs a pure-DP replicated sub-mesh: tables
    replicated over the replica axis, batch sharded over all axes —
    results identical to the single-device engine (closing the ROADMAP
    "dp_axes through the Engine" item)."""
    code = """
    import dataclasses
    import numpy as np
    import jax
    from repro.configs.registry import get_dlrm
    from repro.data import make_recsys_batch
    from repro.engine import Engine
    from repro.launch.mesh import make_mesh

    cfg = dataclasses.replace(get_dlrm("dlrm-rm2-small-unsharded").reduced(),
                              batch_size=8)
    mesh = make_mesh((2, 2, 1), ("replica", "data", "model"))
    eng = Engine(cfg, mesh=mesh, dp_axes=("replica",))
    assert eng.embed_devices == 2 and eng.n_devices == 4

    ref_eng = Engine(cfg)
    b = make_recsys_batch(cfg, 0)
    q = {"dense": b["dense"], "indices": b["indices"]}
    sess = eng.serve_session(max_batch_queries=4, max_wait_ms=1e6)
    futs = [sess.submit(q, now=0.0) for _ in range(4)]
    assert all(f.done for f in futs)
    ref = ref_eng.serve_session(max_batch_queries=1).serve_direct(
        q["dense"], q["indices"])
    np.testing.assert_allclose(futs[0].probs, ref, rtol=1e-5, atol=1e-6)

    t_dp = eng.train_session(); t_dp.run(3)
    t_ref = ref_eng.train_session(); t_ref.run(3)
    for a, b2 in zip(jax.tree_util.tree_leaves(t_dp.params),
                     jax.tree_util.tree_leaves(t_ref.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b2),
                                   rtol=1e-5, atol=1e-6)
    print("DP-OK")
    """
    proc = subproc(code, n_devices=4)
    assert proc.returncode == 0, proc.stderr
    assert "DP-OK" in proc.stdout


def test_bench_run_only_rejects_typo():
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "nosuchsection"],
        capture_output=True, text=True, cwd=REPO, timeout=300,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")})
    assert proc.returncode == 2, proc.stderr
    assert "invalid choice" in proc.stderr
    assert "tiered_embedding" in proc.stderr   # valid names are listed
