"""repro.cluster: fleet serving correctness on CPU meshes.

The invariants the subsystem must hold:

  * routing is a pure dispatch decision — every policy serves the exact
    same per-query results as a single-board session;
  * the autoscaler's scale-up re-places live params through
    `runtime/elastic.remesh_tree` onto a REAL sub-mesh without changing
    served results (subprocess, 8 virtual devices);
  * the hit-ratio monitor detects zipf_drift erosion and its
    `tiered_embedding.lfu_refresh` restores the hit ratio;
  * the bench is registered in benchmarks/run.py.
"""
import dataclasses
import os
import sys
from types import SimpleNamespace

import numpy as np
import pytest

from repro.configs.registry import get_dlrm
from repro.engine import Engine
from repro.traffic import make_scenario, materialize_query

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _cfg():
    return dataclasses.replace(
        get_dlrm("dlrm-rm2-small-unsharded").reduced(), batch_size=8)


# ---------------------------------------------------------------------------
# Routers (unit: fake replicas)
# ---------------------------------------------------------------------------
def _fake(rid, wait):
    return SimpleNamespace(rid=rid, expected_wait_s=lambda now, w=wait: w,
                           backlog=lambda now: 0)


def test_router_policies_unit():
    from repro.cluster import make_router

    reps = [_fake(0, 5.0), _fake(1, 1.0), _fake(2, 3.0)]
    rr = make_router("round_robin")
    assert [rr.pick(reps, 0.0).rid for _ in range(4)] == [0, 1, 2, 0]
    rr.replica_removed(reps[:2])               # shrink: index must re-wrap
    assert rr.pick(reps[:2], 0.0).rid in (0, 1)

    jsq = make_router("jsq")
    assert jsq.pick(reps, 0.0).rid == 1        # global min expected wait
    p2c = make_router("p2c", seed=0)
    picks = {p2c.pick(reps, 0.0).rid for _ in range(32)}
    assert 0 not in picks                      # never joins the longest queue
    assert p2c.pick(reps[:1], 0.0).rid == 0    # single replica degenerates

    with pytest.raises(ValueError, match="unknown router"):
        make_router("nosuch")


def test_autoscaler_policy_unit():
    from repro.cluster import SLAAutoscaler

    auto = SLAAutoscaler(10.0, max_replicas=3, window=4, patience=2,
                         scale_down_frac=0.3, cooldown_s=1.0)
    # sustained violation: two consecutive full windows above SLA -> up
    assert auto.observe([20.0] * 4, now=0.0, n_replicas=1) is None
    act = auto.observe([20.0] * 4, now=0.1, n_replicas=1)
    assert act is not None and act[0] == "up" and act[1] > 10.0
    # cooldown (until 1.1) holds even under continued violation
    assert auto.observe([20.0] * 4, now=0.3, n_replicas=2) is None
    assert auto.observe([20.0] * 4, now=0.5, n_replicas=2) is None
    # sustained slack after cooldown -> down (but never below min)
    assert auto.observe([1.0] * 4, now=2.0, n_replicas=2) is None
    act = auto.observe([1.0] * 4, now=2.1, n_replicas=2)
    assert act is not None and act[0] == "down"
    auto2 = SLAAutoscaler(10.0, min_replicas=1, window=2, patience=1)
    assert auto2.observe([1.0] * 2, now=0.0, n_replicas=1) is None

    with pytest.raises(ValueError, match="min_replicas"):
        SLAAutoscaler(10.0, min_replicas=3, max_replicas=2)


# ---------------------------------------------------------------------------
# Cluster runs (in-process, replicas share the single CPU device)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", ["round_robin", "jsq", "p2c"])
def test_router_policies_preserve_results(policy):
    """Any routing policy == single-board serving, query for query."""
    from repro.cluster import Cluster

    cfg = _cfg()
    events = make_scenario("stationary", alpha=1.05).events(
        10, qps=400.0, seed=1)
    cluster = Cluster(cfg, n_replicas=2, alpha=1.05, router=policy,
                      max_batch_queries=2, max_wait_ms=2.0)
    report = cluster.run(events, sla_ms=1e6, scenario="stationary")
    assert report.n_queries == 10 and report.router == policy
    assert sorted(cluster.completed) == [e.qid for e in events]
    ref = Engine(cfg, alpha=1.05).serve_session(max_batch_queries=2)
    for ev in events:
        q = materialize_query(cfg, ev, cluster.query_size)
        expect = ref.serve_direct(q["dense"], q["indices"])
        np.testing.assert_allclose(
            cluster.completed[ev.qid].probs, expect, rtol=1e-5, atol=1e-6,
            err_msg=f"qid={ev.qid} policy={policy}")


def test_cluster_report_shape():
    from repro.cluster import Cluster

    cfg = _cfg()
    events = make_scenario("diurnal", alpha=1.05, period_s=0.1).events(
        8, qps=300.0, seed=0)
    report = Cluster(cfg, n_replicas=2, alpha=1.05, max_batch_queries=2
                     ).run(events, sla_ms=1e6, scenario="diurnal")
    assert report.scenario == "diurnal"
    assert report.n_replicas_start == report.n_replicas_end == 2
    assert report.p50_ms <= report.p90_ms <= report.p99_ms
    assert report.achieved_qps > 0 and report.offered_qps > 0
    assert len(report.replicas) == 2
    assert all(0.0 <= s["util"] <= 1.0 for s in report.replicas)
    assert sum(s["served"] for s in report.replicas) == 8
    assert report.predicted_qps is None        # plan="none"
    assert "PASS" in report.summary()


def test_cluster_auto_plan_predicts_qps():
    from repro.cluster import Cluster

    cfg = _cfg()
    events = make_scenario("stationary", alpha=1.05).events(
        6, qps=300.0, seed=0)
    cl = Cluster(cfg, n_replicas=2, alpha=1.05, plan="auto",
                 max_batch_queries=2)
    report = cl.run(events, sla_ms=1e6, scenario="stationary")
    assert cl.plan_report is not None
    assert report.predicted_qps == pytest.approx(
        2 * cl.plan_report.predicted_qps)
    assert "PlanReport" in report.summary()


def test_autoscaler_scales_and_preserves_results(subproc):
    """Scale-up on a REAL sub-mesh split: 8 virtual devices, 2-device
    replicas. The tiny SLA forces a scale-up mid-run; the new replica's
    params arrive via remesh_tree and every served result still matches
    the single-board reference. A second run with huge SLA + min_replicas
    scales DOWN and results still match: the up/down round trip through
    remesh_tree is output-transparent."""
    code = """
    import dataclasses
    import numpy as np
    from repro.configs.registry import get_dlrm
    from repro.cluster import Cluster, SLAAutoscaler
    from repro.engine import Engine
    from repro.traffic import make_scenario, materialize_query

    cfg = dataclasses.replace(get_dlrm("dlrm-rm2-small-unsharded").reduced(),
                              batch_size=8)
    events = make_scenario("stationary", alpha=1.05).events(40, qps=2000.0,
                                                            seed=2)
    ref = Engine(cfg, alpha=1.05).serve_session(max_batch_queries=2)

    # up: impossible SLA -> grow to max_replicas
    auto = SLAAutoscaler(sla_ms=1e-3, max_replicas=3, window=8, patience=1)
    cl = Cluster(cfg, n_replicas=1, devices_per_replica=2, alpha=1.05,
                 router="jsq", max_batch_queries=2, autoscaler=auto)
    rep = cl.run(events, sla_ms=1e6)
    ups = [e for e in rep.scale_events if e.action == "up"]
    assert rep.n_replicas_end == 3 and len(ups) == 2, rep.scale_events
    assert all(e.remesh.get("resharded", 0) > 0 for e in ups), ups
    assert all(e.remesh.get("replicated_fallback", 1) == 0 for e in ups)
    meshes = {id(r.mesh) for r in cl.replicas}
    assert len(meshes) == 3                      # distinct sub-meshes
    for ev in events:
        q = materialize_query(cfg, ev, cl.query_size)
        np.testing.assert_allclose(cl.completed[ev.qid].probs,
                                   ref.serve_direct(q["dense"], q["indices"]),
                                   rtol=1e-5, atol=1e-6)

    # down: huge SLA -> shed back to min_replicas, results still exact
    auto2 = SLAAutoscaler(sla_ms=1e6, min_replicas=1, max_replicas=3,
                          window=8, patience=1)
    cl2 = Cluster(cfg, n_replicas=2, devices_per_replica=2, alpha=1.05,
                  router="jsq", max_batch_queries=2, autoscaler=auto2)
    rep2 = cl2.run(events, sla_ms=1e6)
    downs = [e for e in rep2.scale_events if e.action == "down"]
    assert rep2.n_replicas_end == 1 and downs, rep2.scale_events
    for ev in events:
        q = materialize_query(cfg, ev, cl2.query_size)
        np.testing.assert_allclose(cl2.completed[ev.qid].probs,
                                   ref.serve_direct(q["dense"], q["indices"]),
                                   rtol=1e-5, atol=1e-6)
    print("SCALE-OK")
    """
    proc = subproc(code, n_devices=8)
    assert proc.returncode == 0, proc.stderr
    assert "SCALE-OK" in proc.stdout


def test_drift_refresh_restores_hit_ratio():
    """zipf_drift erodes the monitor's elected hot set; the drift-triggered
    lfu_refresh (live counts) restores the windowed hit ratio. Pure
    monitor-level check — no serving, fully deterministic."""
    from repro.cluster import HitRatioMonitor

    cfg = _cfg()
    sc = make_scenario("zipf_drift", alpha=1.2, rotate_every_s=0.3,
                       salt_stride=37)
    events = sc.events(200, qps=400.0, seed=4)
    salts = {e.perm_salt for e in events}
    assert salts == {0, 37}, salts              # exactly one rotation
    mon = HitRatioMonitor(cfg, alpha=1.2, window=12, cooldown_queries=20)
    assert mon.baseline > 0.4
    pre, post_drift, post_refresh = [], [], []
    for ev in events:
        q = materialize_query(cfg, ev, cfg.batch_size)
        h = mon.observe(ev.qid, q["indices"], ev.arrival_s)
        fired = mon.maybe_refresh(ev.arrival_s)
        if ev.perm_salt == 0:
            pre.append(h)
        elif not mon.refreshes:
            post_drift.append(h)
        elif not fired:
            post_refresh.append(h)
    assert len(mon.refreshes) == 1, mon.refreshes
    assert np.mean(pre) > 0.8 * mon.baseline
    assert np.mean(post_drift) < 0.3 * mon.baseline     # erosion
    tail = post_refresh[-20:]
    assert np.mean(tail) > 0.8 * mon.baseline, np.mean(tail)  # recovery


def test_monitor_service_multiplier_tracks_hit_ratio():
    """Hybrid-memory retiming: losing the fast tier must cost service
    time (multiplier > 1 vs baseline, monotone in the deficit)."""
    from repro.cluster import HitRatioMonitor

    cfg = _cfg()
    mon = HitRatioMonitor(cfg, alpha=1.2,
                          model_cfg=get_dlrm("dlrm-rm2-small-unsharded"))
    at_base = mon.service_multiplier(mon.baseline)
    assert at_base == pytest.approx(1.0)
    degraded = mon.service_multiplier(0.1)
    mild = mon.service_multiplier(0.8 * mon.baseline)
    assert degraded > mild > at_base
    assert degraded > 1.5                      # full-scale lookups dominate


def test_straggler_service_scale_applies():
    from repro.cluster import Cluster

    cfg = _cfg()
    events = make_scenario("stationary", alpha=1.05).events(
        12, qps=2000.0, seed=0)
    fast = Cluster(cfg, n_replicas=2, alpha=1.05, max_batch_queries=2,
                   router="round_robin")
    slow = Cluster(cfg, n_replicas=2, alpha=1.05, max_batch_queries=2,
                   router="round_robin", service_scales=(1.0, 20.0))
    rf = fast.run(events, sla_ms=1e6)
    rs = slow.run(events, sla_ms=1e6)
    assert rs.p99_ms > 2.0 * rf.p99_ms, (rs.p99_ms, rf.p99_ms)
    with pytest.raises(ValueError, match="service_scales"):
        Cluster(cfg, n_replicas=2, service_scales=(1.0,))


def test_bench_cluster_registered():
    if REPO not in sys.path:
        sys.path.insert(0, REPO)
    from benchmarks import run as bench_run

    assert "cluster" in {name for name, _ in bench_run.SECTIONS}


def test_cluster_cost_accounting_fields():
    """Autoscaler-economics satellite: ClusterReport carries board-seconds
    and an SLA-violation count; scale decisions log the running cost."""
    from repro.cluster import Cluster, SLAAutoscaler

    cfg = _cfg()
    events = make_scenario("stationary", alpha=1.05).events(
        10, qps=400.0, seed=1)
    cl = Cluster(cfg, n_replicas=2, alpha=1.05, max_batch_queries=2)
    r = cl.run(events, sla_ms=1e6, scenario="stationary")
    # fixed fleet: boards x makespan exactly
    assert r.board_seconds == pytest.approx(2 * r.makespan_s)
    assert r.sla_violations == 0
    assert "board-seconds" in r.summary()
    # a tiny SLA turns every query into a violation (latency is real)
    r2 = Cluster(cfg, n_replicas=2, alpha=1.05, max_batch_queries=2
                 ).run(events, sla_ms=1e-6, scenario="stationary")
    assert r2.sla_violations == r2.n_queries

    # scale decisions record the running board-seconds on the event AND in
    # the autoscaler's cost log
    auto = SLAAutoscaler(sla_ms=1e-3, max_replicas=2, window=4, patience=1)
    cl3 = Cluster(cfg, n_replicas=1, alpha=1.05, max_batch_queries=2,
                  autoscaler=auto)
    r3 = cl3.run(events, sla_ms=1e6, scenario="stationary")
    ups = [e for e in r3.scale_events if e.action == "up"]
    assert ups, r3.scale_events
    assert all(e.board_seconds >= 0.0 for e in ups)
    assert len(auto.cost_log) == len(r3.scale_events)
    assert auto.cost_log[0][1] == pytest.approx(ups[0].board_seconds)


def test_monitor_service_multiplier_injectable():
    """Calibration satellite: a measured override replaces the modeled
    hybrid-memory retiming curve; default behavior is unchanged."""
    from repro.cluster import HitRatioMonitor

    cfg = _cfg()
    measured = {0.9: 1.0, 0.1: 3.5}
    mon = HitRatioMonitor(
        cfg, alpha=1.2,
        service_multiplier=lambda h: measured[round(h, 1)])
    assert mon.service_multiplier(0.9) == 1.0
    assert mon.service_multiplier(0.1) == 3.5

    const = HitRatioMonitor(cfg, alpha=1.2, service_multiplier=2.5)
    assert const.service_multiplier(0.42) == 2.5

    with pytest.raises(ValueError, match="service_multiplier"):
        HitRatioMonitor(cfg, alpha=1.2, service_multiplier="fast")

    default = HitRatioMonitor(cfg, alpha=1.2,
                              model_cfg=get_dlrm("dlrm-rm2-small-unsharded"))
    assert default.service_multiplier(default.baseline) == pytest.approx(1.0)
    assert default.service_multiplier(0.05) > 1.0
