"""Shared test fixtures. NOTE: no XLA_FLAGS here — smoke tests must see the
real single CPU device; distributed tests spawn subprocesses that set
--xla_force_host_platform_device_count themselves."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 600
                     ) -> subprocess.CompletedProcess:
    """Run `code` in a subprocess with n virtual CPU devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC
    return subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env)


@pytest.fixture(scope="session")
def subproc():
    return run_with_devices
