"""repro.obs: tracing, metrics, and per-query latency attribution.

The invariants the observability layer must hold:

  * Chrome trace export is schema-valid (every event carries
    name/ph/ts/pid/tid), every track's "B"/"E" pairs balance — including
    back-to-back and exactly-nested spans on tie timestamps — and
    zero-length spans degrade to instants instead of unbalancing;
  * the metrics registry keys series by (name, labels), refuses kind
    conflicts and negative counter increments, and snapshots to a plain
    JSON-ready dict;
  * attribution closes BY CONSTRUCTION: every `QueryRecord`'s six
    components sum to its latency (hypothesis fuzzes random flush
    timelines), and `BlameReport` separates the tail's decomposition
    from the median's;
  * end-to-end: a traced 2-board sharded-fleet flash-crowd run (with a
    live autoscaler remesh) produces a valid trace with spans from >= 4
    layers, populated metrics, a closing blame report, and a
    JSON-serializable report — same for the replicated cluster;
  * `write_bench_json` attaches a metrics snapshot when given one.
"""
import dataclasses
import json
import os

import numpy as np
import pytest

from repro.configs.registry import get_dlrm
from repro.obs import (AttributionLog, BlameReport, COMPONENTS,
                       MetricsRegistry, Tracer, default_registry,
                       interval_overlap_s)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

REQUIRED_KEYS = {"name", "ph", "ts", "pid", "tid"}


def _cfg(**kw):
    return dataclasses.replace(
        get_dlrm("dlrm-rm2-small-unsharded").reduced(), batch_size=8, **kw)


def _check_balanced(events):
    """Every (pid, tid) track's B/E pairs must nest like parentheses."""
    depth = {}
    stacks = {}
    for e in events:
        if e["ph"] not in ("B", "E"):
            continue
        key = (e["pid"], e["tid"])
        stack = stacks.setdefault(key, [])
        if e["ph"] == "B":
            stack.append(e["name"])
        else:
            assert stack, f"E with empty stack on track {key}: {e}"
            assert stack.pop() == e["name"], f"mispaired E on {key}: {e}"
        depth[key] = len(stack)
    for key, stack in stacks.items():
        assert not stack, f"unclosed spans on track {key}: {stack}"


# ---------------------------------------------------------------------------
# Tracer (unit)
# ---------------------------------------------------------------------------
def test_tracer_chrome_schema_and_track_names():
    tr = Tracer()
    tr.track(1, 0, process="board0", thread="serve")
    tr.span("a", "service", 0.0, 1e-3, pid=1, tid=0, args={"queries": 2})
    tr.instant("flush:full", "batching", 0.5e-3, pid=1, tid=0)
    tr.counter("queue_depth", 0.2e-3, {"board0": 3}, pid=1)
    doc = tr.to_chrome_json()
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    evs = doc["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    timed = [e for e in evs if e["ph"] != "M"]
    # metadata names the track, and comes before any timed event
    assert {m["name"] for m in meta} == {"process_name", "thread_name"}
    assert evs[:len(meta)] == meta
    for e in timed:
        assert REQUIRED_KEYS <= set(e), e
        assert "_seq" not in e
    # virtual seconds became microseconds
    assert [e["ts"] for e in timed if e["ph"] == "B"] == [0.0]
    assert [e["ts"] for e in timed if e["ph"] == "E"] == [1000.0]
    # instants carry scope, counters carry float args
    (inst,) = [e for e in timed if e["ph"] == "i"]
    assert inst["s"] == "t"
    (ctr,) = [e for e in timed if e["ph"] == "C"]
    assert ctr["args"] == {"board0": 3.0}
    _check_balanced(timed)


def test_tracer_tie_ordering_keeps_tracks_balanced():
    tr = Tracer()
    # back-to-back spans sharing a timestamp: E must sort before B
    tr.span("first", "service", 0.0, 1.0, pid=1, tid=0)
    tr.span("second", "service", 1.0, 2.0, pid=1, tid=0)
    # exact nesting, emitted outer-first, both ends tie
    tr.span("outer", "service", 3.0, 4.0, pid=1, tid=1)
    tr.span("inner", "service", 3.0, 4.0, pid=1, tid=1)
    timed = [e for e in tr.to_chrome_json()["traceEvents"]
             if e["ph"] != "M"]
    _check_balanced(timed)
    ts = [e["ts"] for e in timed]
    assert ts == sorted(ts), "export must be time-ordered"


def test_tracer_zero_length_span_degrades_and_negative_raises():
    tr = Tracer()
    tr.span("empty", "service", 1.0, 1.0, pid=0, tid=0)
    assert [e["ph"] for e in tr.events] == ["i"]
    with pytest.raises(ValueError):
        tr.span("backwards", "service", 2.0, 1.0)


# ---------------------------------------------------------------------------
# MetricsRegistry (unit)
# ---------------------------------------------------------------------------
def test_metrics_registry_series_and_snapshot():
    reg = MetricsRegistry()
    reg.counter("wire_bytes", board=0).inc(128)
    reg.counter("wire_bytes", board=0).inc(64)     # same series
    reg.counter("wire_bytes", board=1).inc(32)     # distinct label set
    reg.gauge("queue_depth", rid=1).set(3)
    reg.histogram("flush_service_ms").observe(4.2)
    reg.histogram("flush_service_ms").observe(1.0)
    snap = reg.snapshot()
    assert snap["wire_bytes{board=0}"] == 192.0
    assert snap["wire_bytes{board=1}"] == 32.0
    assert snap["queue_depth{rid=1}"] == 3.0
    h = snap["flush_service_ms"]
    assert h["count"] == 2 and h["min"] == 1.0 and h["max"] == 4.2
    assert sum(h["buckets"].values()) == 2
    assert json.loads(json.dumps(snap)) == snap    # JSON-ready
    # scalar reads
    assert reg.value("wire_bytes", board=0) == 192.0
    assert reg.value("never_published", default=7.0) == 7.0
    assert reg.total("wire_bytes") == 224.0
    reg.reset()
    assert len(reg) == 0 and reg.snapshot() == {}


def test_metrics_registry_guards():
    reg = MetricsRegistry()
    reg.counter("x").inc()
    with pytest.raises(ValueError):
        reg.gauge("x")                     # kind conflict on one name
    with pytest.raises(ValueError):
        reg.counter("y").inc(-1)           # counters are monotone
    with pytest.raises(ValueError):
        reg.histogram("h").observe(1.0) or reg.value("h")
    assert default_registry() is default_registry()


# ---------------------------------------------------------------------------
# Attribution (unit)
# ---------------------------------------------------------------------------
def test_interval_overlap():
    ivals = [(1.0, 2.0), (3.0, 4.0)]
    assert interval_overlap_s(0.0, 5.0, ivals) == 2.0
    assert interval_overlap_s(1.5, 3.5, ivals) == 1.0
    assert interval_overlap_s(2.0, 3.0, ivals) == 0.0
    assert interval_overlap_s(5.0, 5.0, ivals) == 0.0


def test_attribution_closes_and_splits_barrier_from_queue():
    log = AttributionLog()
    # wait [1.0, 1.6] overlaps a remesh barrier [1.2, 1.5] for 0.3s
    log.record_batch([(0, 0.4), (1, 0.7)], rid=1, trigger=1.0, start=1.6,
                     done=1.9, compute_s=0.2, link_stall_s=0.05,
                     swap_stall_s=0.02, queue_extra_s=0.03,
                     barriers=[(1.2, 1.5)])
    assert len(log) == 2
    r = log.records[0]
    assert r.remesh_barrier_s == pytest.approx(0.3)
    assert r.queue_wait_s == pytest.approx(0.3 + 0.03)
    assert r.batch_wait_s == pytest.approx(0.6)
    assert abs(r.residual_s()) < 1e-9
    assert set(r.components_s()) == set(COMPONENTS)
    # second query arrived later -> smaller batch_wait, same closure
    assert log.records[1].batch_wait_s == pytest.approx(0.3)
    assert abs(log.records[1].residual_s()) < 1e-9


def test_blame_report_separates_tail_from_median():
    log = AttributionLog()
    # 19 fast compute-bound queries + 1 queue-bound straggler
    for q in range(19):
        t = q * 1.0
        log.record_batch([(q, t)], rid=0, trigger=t, start=t,
                         done=t + 0.010, compute_s=0.010)
    log.record_batch([(19, 19.0)], rid=0, trigger=19.0, start=19.090,
                     done=19.1, compute_s=0.010)
    blame = log.blame(percentile=95.0)
    assert isinstance(blame, BlameReport)
    assert blame.n_queries == 20 and blame.n_tail >= 1
    assert blame.dominant_tail == "queue_wait"
    assert blame.median_ms["queue_wait"] == pytest.approx(0.0)
    assert blame.tail_ms["queue_wait"] == pytest.approx(90.0)
    assert blame.max_residual_ms < 1e-6
    s = blame.summary()
    assert "queue_wait" in s and "[blame]" in s
    assert AttributionLog().blame() is None


# ---------------------------------------------------------------------------
# Attribution closure (property)
# ---------------------------------------------------------------------------
def test_attribution_closure_property():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    secs = st.floats(0.0, 10.0, allow_nan=False, allow_infinity=False)
    small = st.floats(0.0, 1.0, allow_nan=False, allow_infinity=False)

    @st.composite
    def flushed_batch(draw):
        trigger = draw(secs)
        arrivals = draw(st.lists(st.floats(0.0, 1.0), min_size=1,
                                 max_size=6))
        start = trigger + draw(small)
        done = start + draw(small) + 1e-6
        barriers = [(trigger - draw(small), trigger + draw(small))
                    for _ in range(draw(st.integers(0, 3)))]
        return dict(
            queries=[(i, trigger - a) for i, a in enumerate(arrivals)],
            trigger=trigger, start=start, done=done,
            compute_s=draw(small), link_stall_s=draw(small),
            swap_stall_s=draw(small), queue_extra_s=draw(small),
            barriers=barriers)

    @settings(max_examples=200, deadline=None)
    @given(st.lists(flushed_batch(), min_size=1, max_size=8))
    def run(batches):
        log = AttributionLog()
        for b in batches:
            log.record_batch(b.pop("queries"), rid=0, **b)
        for r in log.records:
            # the closure invariant: components sum to latency exactly
            # (up to float addition order)
            assert abs(r.residual_s()) <= 1e-9 * max(1.0, r.latency_s)
            assert r.remesh_barrier_s >= 0 and r.queue_wait_s >= 0
        blame = log.blame()
        assert blame.max_residual_ms <= 1e-6 * max(1.0, blame.threshold_ms)

    run()


# ---------------------------------------------------------------------------
# End-to-end: traced runs
# ---------------------------------------------------------------------------
def test_traced_sharded_fleet_flash_crowd(tmp_path):
    """The acceptance scenario: a recorded flash-crowd on a 2-board fleet
    with a live autoscaler produces a valid Chrome trace with spans from
    >= 4 layers, a closing blame report, populated metrics, and a
    JSON-round-trippable report."""
    from repro.cluster.autoscale import SLAAutoscaler
    from repro.fabric import ShardedFleet
    from repro.traffic import make_scenario

    cfg = _cfg()
    events = make_scenario("flash_crowd", alpha=1.05).events(
        60, qps=800.0, seed=5)
    tracer = Tracer()
    auto = SLAAutoscaler(0.5, min_replicas=2, max_replicas=4, window=8,
                         patience=1, cooldown_s=0.005)
    fleet = ShardedFleet(cfg, n_boards=2, alpha=1.05, max_batch_queries=2,
                         autoscaler=auto, tracer=tracer)
    r = fleet.run(events, sla_ms=1e6, scenario="flash_crowd")
    assert any(e.action == "up" for e in r.scale_events)

    # -- trace: schema, balance, layer coverage
    path = tracer.write(str(tmp_path / "trace.json"))
    doc = json.load(open(path))
    timed = [e for e in doc["traceEvents"] if e["ph"] != "M"]
    assert timed, "trace is empty"
    for e in timed:
        assert REQUIRED_KEYS <= set(e)
    _check_balanced(timed)
    cats = {e["cat"] for e in timed}
    # batching, service, fabric, autoscaler (+ counters) = >= 4 layers
    assert {"batching", "service", "fabric", "autoscaler"} <= cats
    names = {e["name"] for e in timed}
    assert {"batch_fill", "serve_batch", "owner_lookup",
            "remesh_barrier"} <= names
    # scale decisions land on the pid-0 control track; per-board remesh
    # barriers land on the board pids; each board serves on its own pid
    assert {e["pid"] for e in timed
            if e["name"].startswith("scale:")} == {0}
    assert all(e["pid"] > 0 for e in timed
               if e["name"] == "remesh_barrier")
    assert len({e["pid"] for e in timed if e["cat"] == "service"}) >= 2

    # -- attribution: every query closes; the blame report rides the report
    assert len(fleet.attribution) == len(events)
    assert r.blame is not None
    assert r.blame.max_residual_ms < 1e-6
    assert r.blame.n_queries == len(events)
    assert sum(r.blame.tail_ms.values()) > 0
    assert "[blame]" in r.summary()
    # the remesh actually charged barrier time to some query
    assert any(q.remesh_barrier_s > 0 for q in fleet.attribution.records)

    # -- metrics: the registry carries the fleet's wire/migration tallies
    snap = fleet.metrics.snapshot()
    assert snap["remote_lookups"] > 0
    assert snap["migrations{action=up}"] >= 1
    assert any(k.startswith("wire_bytes{board=") for k in snap)
    assert snap["flush_service_ms"]["count"] > 0

    # -- report: serializes, round-trips, carries the blame decomposition
    rpath = tmp_path / "report.json"
    r.to_json(str(rpath))
    d = json.loads(rpath.read_text())
    assert d["kind"] == "FabricReport"
    assert d["blame"]["kind"] == "BlameReport"
    assert set(d["blame"]["tail_ms"]) == set(COMPONENTS)
    assert d["n_queries"] == len(events)


def test_traced_cluster_and_serial_session(tmp_path):
    """Replicated-cluster and single-board paths trace + attribute too."""
    from repro.cluster import Cluster
    from repro.engine import Engine
    from repro.traffic import make_scenario

    cfg = _cfg()
    events = make_scenario("stationary", alpha=1.05).events(
        40, qps=800.0, seed=3)
    tracer = Tracer()
    cl = Cluster(cfg, n_replicas=2, alpha=1.05, max_batch_queries=2,
                 tracer=tracer)
    r = cl.run(events, sla_ms=1e6)
    timed = [e for e in tracer.to_chrome_json()["traceEvents"]
             if e["ph"] != "M"]
    _check_balanced(timed)
    assert {"batching", "service"} <= {e["cat"] for e in timed}
    assert len(cl.attribution) == len(events)
    assert r.blame is not None and r.blame.max_residual_ms < 1e-6
    assert json.loads(r.to_json())["kind"] == "ClusterReport"
    assert cl.metrics.snapshot()["queries_served{rid=0}"] > 0

    # single-board serial loop: spans + closure through the same machinery
    tr2 = Tracer()
    session = Engine(cfg).serve_session(max_batch_queries=2)
    sr = session.run_serial(4, tracer=tr2)
    timed2 = [e for e in tr2.to_chrome_json()["traceEvents"]
              if e["ph"] != "M"]
    _check_balanced(timed2)
    assert sum(e["ph"] == "B" for e in timed2) == 4
    assert sr.blame is not None and sr.blame.max_residual_ms < 1e-6
    assert json.loads(sr.to_json())["kind"] == "SLAReport"


# ---------------------------------------------------------------------------
# Report serialization + bench artifact
# ---------------------------------------------------------------------------
def test_plan_report_serializes():
    from repro.engine import Engine

    eng = Engine(_cfg(), plan="auto")
    eng.build_plan("inference")          # plan reports build lazily
    pr = eng.plan_report("inference")
    d = json.loads(pr.to_json())
    assert d["kind"] == "PlanReport"
    assert d["plan"]["kind"] == "ShardingPlan"
    assert d["predicted_qps"] == pytest.approx(pr.predicted_qps)


def test_write_bench_json_metrics_section(tmp_path):
    from benchmarks._artifacts import write_bench_json

    reg = MetricsRegistry()
    reg.counter("wire_bytes", board=0).inc(77)
    path = write_bench_json(
        "obs_selftest", [("claim", True, "ok")], {"x": 1.0},
        out_dir=str(tmp_path), metrics=reg.snapshot())
    d = json.load(open(path))
    assert d["ok"] is True
    assert d["metrics"] == {"wire_bytes{board=0}": 77.0}
    # omitted -> no section at all (older artifacts stay byte-stable)
    path2 = write_bench_json(
        "obs_selftest2", [("claim", True, "ok")], {"x": 1.0},
        out_dir=str(tmp_path))
    assert "metrics" not in json.load(open(path2))
