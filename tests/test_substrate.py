"""Substrate tests: checkpoint, data, runtime, optim, hlo_analysis."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, latest_step, restore, save
from repro.data import make_lm_batch, make_recsys_batch
from repro.configs.registry import ARCHS, get_dlrm
from repro.launch import hlo_analysis
from repro.optim import adagrad, adamw, sgd
from repro.runtime import StepTimer, StragglerPolicy
from repro.runtime.straggler import Action


# ---------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    tree = {"w": jnp.arange(12.0).reshape(3, 4), "b": [jnp.ones(3), {"x": jnp.zeros(2)}]}
    save(str(tmp_path), 7, tree, {"note": "hi"})
    out, step, meta = restore(str(tmp_path), tree)
    assert step == 7 and meta["note"] == "hi"
    for a, b in zip(jax.tree_util.tree_leaves(out), jax.tree_util.tree_leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity_no_partial_visible(tmp_path):
    """A missing manifest (simulated crash) is never listed as latest."""
    tree = {"a": jnp.ones(4)}
    save(str(tmp_path), 1, tree)
    # simulate a crashed write: directory without manifest
    os.makedirs(tmp_path / "step_00000002")
    assert latest_step(str(tmp_path)) == 1


def test_checkpoint_manager_async_and_retention(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.zeros(8)}
    for s in (1, 2, 3, 4):
        m.save(s, {"a": jnp.full(8, float(s))})
    m.wait()
    steps = sorted(int(p.split("_")[1]) for p in os.listdir(tmp_path)
                   if p.startswith("step_"))
    assert steps == [3, 4]
    out, step, _ = m.restore(tree)
    assert step == 4 and float(np.asarray(out["a"])[0]) == 4.0


def test_checkpoint_structure_mismatch_raises(tmp_path):
    save(str(tmp_path), 1, {"a": jnp.ones(2)})
    with pytest.raises(ValueError):
        restore(str(tmp_path), {"a": jnp.ones(2), "b": jnp.ones(2)})


# ---------------------------------------------------------------- data
def test_recsys_batches_deterministic_and_distinct():
    cfg = get_dlrm("dlrm-rm2-small-unsharded").reduced()
    b1 = make_recsys_batch(cfg, 5, seed=1)
    b2 = make_recsys_batch(cfg, 5, seed=1)
    b3 = make_recsys_batch(cfg, 6, seed=1)
    for k in b1:
        np.testing.assert_array_equal(np.asarray(b1[k]), np.asarray(b2[k]))
    assert not np.array_equal(np.asarray(b1["indices"]), np.asarray(b3["indices"]))


def test_lm_batch_labels_are_next_tokens():
    cfg = ARCHS["internlm2-1.8b"].reduced()
    b = make_lm_batch(cfg, 0, seed=0, batch=2, seq=32)
    assert b["tokens"].shape == (2, 31) and b["labels"].shape == (2, 31)
    # labels[t] == tokens[t+1] by construction
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


# ---------------------------------------------------------------- straggler
def test_step_timer_flags_outliers():
    t = StepTimer()
    for _ in range(20):
        t.record(1.0)
    assert t.is_straggler_step(2.0)
    assert not t.is_straggler_step(1.01)


def test_straggler_policy_escalates():
    p = StragglerPolicy(log_after=1, reshuffle_after=2, evict_after=3)
    acts = [p.report("h1", True) for _ in range(3)]
    assert acts == [Action.LOG, Action.RESHUFFLE, Action.EVICT]
    assert p.report("h2", False) == Action.NONE


def test_straggler_strikes_decay():
    p = StragglerPolicy(decay_every=4, evict_after=100)
    for _ in range(2):
        p.report("h1", True)
    for _ in range(8):
        p.report("h1", False)
    assert p.strikes["h1"] < 2


# ---------------------------------------------------------------- optim
def _quad_loss(w):
    return jnp.sum((w - 3.0) ** 2)


@pytest.mark.parametrize("opt", [sgd(0.1), sgd(0.05, momentum=0.9),
                                 adagrad(0.9), adamw(0.2, weight_decay=0.0)])
def test_optimizers_minimize_quadratic(opt):
    w = jnp.zeros(4)
    state = opt.init(w)
    for _ in range(150):
        g = jax.grad(_quad_loss)(w)
        upd, state = opt.update(g, state, w)
        w = w + upd
    assert float(_quad_loss(w)) < 1e-2, opt.name


# ---------------------------------------------------------------- hlo analysis
SYNTH_HLO = """
HloModule synth, num_partitions=4

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8] get-tuple-element(%p), index=1
  %ar = f32[8,8]{1,0} all-reduce(%x), replica_groups=[1,4]<=[4], to_apply=%add
  %d = f32[8,8]{1,0} dot(%ar, %ar), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[8,8]) tuple(%i, %d)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8] parameter(0)
  %init = (s32[], f32[8,8]) tuple(%a, %a)
  %w = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"},"other":1}
  %ag = f32[32,8]{1,0} all-gather(%a), replica_groups=[1,4]<=[4], dimensions={0}
  ROOT %out = f32[8,8] get-tuple-element(%w), index=1
}
"""


def test_hlo_shape_bytes():
    assert hlo_analysis.shape_bytes("f32[8,8]{1,0}") == 256
    assert hlo_analysis.shape_bytes("(s32[], f32[4,4])") == 4 + 64
    assert hlo_analysis.shape_bytes("bf16[2,3]") == 12


def test_hlo_loop_expansion_and_collectives():
    a = hlo_analysis.analyze(SYNTH_HLO)
    # dot: 2*8*8*8 = 1024 flops, x5 trips
    assert a["flops_per_chip"] == 5 * 1024
    # all-reduce 2*256*(3/4)=384 x5 trips; all-gather result-operand = 1024-256
    assert a["collective_by_kind"]["all-reduce"] == 5 * 384
    assert a["collective_by_kind"]["all-gather"] == 768
    assert a["unknown_trip_loops"] == 0


def test_roofline_terms_pick_dominant():
    t = hlo_analysis.roofline_terms(197e12, 100e9, 1e9)
    assert t["bottleneck"] == "compute" and abs(t["t_compute_s"] - 1.0) < 1e-9
    t = hlo_analysis.roofline_terms(1e9, 819e9, 1e9)
    assert t["bottleneck"] == "memory"
    t = hlo_analysis.roofline_terms(1e9, 1e9, 500e9)
    assert t["bottleneck"] == "collective"
