"""THE core correctness property: distributed DLRM (shard_map, Algorithms
1+2) must match the single-device reference bit-for-bit in fp32 — for both
sharding modes, both exchange modes, and both optimizers. Runs in
subprocesses with 8 virtual devices."""
import pytest

CASE = """
import jax, jax.numpy as jnp, numpy as np
assert len(jax.devices()) == 8, jax.devices()
from jax.sharding import PartitionSpec as P
from repro.configs.registry import get_dlrm
from repro.core import dlrm as dlrm_lib
from repro.core import sharding as dsh
from repro.data import make_recsys_batch
from repro.launch.mesh import make_mesh
import dataclasses

cfg = get_dlrm("{config}").reduced()
cfg = dataclasses.replace(cfg, batch_size=32, rows_per_table=128, num_tables=8)
mesh = make_mesh((2, 4), ("data", "model"))

params = dlrm_lib.init_dlrm(jax.random.PRNGKey(0), cfg)
ref_params = jax.tree_util.tree_map(lambda x: x.copy(), params)

step = dsh.make_dlrm_train_step(cfg, mesh, ("data", "model"), lr=0.05,
                                row_wise_exchange="{exchange}",
                                optimizer="{optimizer}")
opt = None
if "{optimizer}" == "adagrad":
    opt = {{"table_acc": jnp.zeros((cfg.num_tables, cfg.rows_per_table), jnp.float32)}}
ref_opt = None if opt is None else jax.tree_util.tree_map(lambda x: x.copy(), opt)

sp = dsh.shard_dlrm_params(params, cfg, mesh, ("data", "model"))
losses = []
for s in range(3):
    b = make_recsys_batch(cfg, s)
    sp, opt, loss = step(sp, opt, b["dense"], b["indices"], b["labels"])
    losses.append(float(loss))

# single-device reference: same algorithm, n=1
for s in range(3):
    b = make_recsys_batch(cfg, s)
    if "{optimizer}" == "sgd":
        ref_params, ref_loss = dlrm_lib.reference_train_step(
            ref_params, b["dense"], b["indices"], b["labels"], cfg, 0.05)
    else:
        # adagrad reference via the row update on a single device
        pooled = dlrm_lib.embedding_bag(ref_params["tables"], b["indices"])
        dp = {{"bot_mlp": ref_params["bot_mlp"], "top_mlp": ref_params["top_mlp"]}}
        def dense_loss(dpp, pl):
            return dlrm_lib.bce_loss(dlrm_lib.dlrm_forward_from_pooled(
                {{**ref_params, **dpp}}, b["dense"], pl), b["labels"])
        grads, gp = jax.grad(dense_loss, argnums=(0, 1))(dp, pooled)
        ref_params = {{**jax.tree_util.tree_map(lambda p, g: p - 0.05 * g, dp, grads),
                      "tables": ref_params["tables"]}}
        B, T, L = b["indices"].shape
        g_rows = jnp.broadcast_to(gp[:, :, None, :], (B, T, L, gp.shape[-1]))
        fi = b["indices"].transpose(1, 0, 2).reshape(T, B * L)
        fg = g_rows.transpose(1, 0, 2, 3).reshape(T, B * L, -1)
        upd = dsh.adagrad_row_update(0.05)
        ref_params["tables"], ref_opt["table_acc"] = upd(
            ref_params["tables"], ref_opt["table_acc"], fi, fg)

for key in ("bot_mlp", "top_mlp", "tables"):
    a = jax.tree_util.tree_leaves(jax.device_get(sp[key]))
    b_ = jax.tree_util.tree_leaves(jax.device_get(ref_params[key]))
    for x, y in zip(a, b_):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=2e-5, atol=2e-5, err_msg=key)
print("MATCH", losses)
"""


@pytest.mark.parametrize("config,exchange,optimizer", [
    ("dlrm-rm2-small-unsharded", "unpooled", "sgd"),
    ("dlrm-rm2-small-sharded", "unpooled", "sgd"),
    ("dlrm-rm2-small-sharded", "partial_pool", "sgd"),
    ("dlrm-rm2-large-unsharded", "unpooled", "adagrad"),
    ("dlrm-rm2-large-sharded", "partial_pool", "adagrad"),
])
def test_distributed_matches_reference(subproc, config, exchange, optimizer):
    r = subproc(CASE.format(config=config, exchange=exchange,
                            optimizer=optimizer))
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MATCH" in r.stdout


SERVE_CASE = """
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro.configs.registry import get_dlrm
from repro.core import dlrm as dlrm_lib
from repro.core import sharding as dsh
from repro.data import make_recsys_batch
from repro.launch.mesh import make_mesh

cfg = get_dlrm("dlrm-rm2-small-sharded").reduced()
cfg = dataclasses.replace(cfg, batch_size=32, rows_per_table=128, num_tables=8)
mesh = make_mesh((2, 4), ("data", "model"))
params = dlrm_lib.init_dlrm(jax.random.PRNGKey(0), cfg)
serve = dsh.make_dlrm_serve_step(cfg, mesh, ("data", "model"), "{exchange}")
sp = dsh.shard_dlrm_params(params, cfg, mesh, ("data", "model"))
b = make_recsys_batch(cfg, 0)
probs = jax.device_get(serve(sp, b["dense"], b["indices"]))
expect = jax.device_get(dlrm_lib.predict(params, b["dense"], b["indices"], cfg))
np.testing.assert_allclose(probs, expect, rtol=2e-5, atol=2e-6)
print("MATCH")
"""


@pytest.mark.parametrize("exchange", ["unpooled", "partial_pool"])
def test_distributed_serve_matches_reference(subproc, exchange):
    r = subproc(SERVE_CASE.format(exchange=exchange))
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MATCH" in r.stdout


CHUNKED_CASE = """
import jax, jax.numpy as jnp, numpy as np, dataclasses, functools
from repro.configs.registry import get_dlrm
from repro.core import dlrm as dlrm_lib
from repro.core import sharding as dsh
from repro.data import make_recsys_batch
from repro.launch.mesh import make_mesh
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

# chunked row-wise lookup == unchunked (associativity of partial pooling)
cfg = get_dlrm("dlrm-rm2-small-sharded").reduced()
cfg = dataclasses.replace(cfg, batch_size=64, rows_per_table=128, num_tables=8)
mesh = make_mesh((8,), ("x",))
params = dlrm_lib.init_dlrm(jax.random.PRNGKey(1), cfg)
b = make_recsys_batch(cfg, 0)

def fwd(chunk):
    def f(tables, idx):
        pooled, _ = dsh.row_wise_forward(tables, idx, "x", 8,
                                         "partial_pool", lookup_chunk=chunk)
        return pooled
    return jax.jit(shard_map(f, mesh=mesh,
                             in_specs=(P(None, "x"), P("x")),
                             out_specs=P("x"), check_rep=False))

p1 = jax.device_get(fwd(8)(params["tables"], b["indices"]))
p2 = jax.device_get(fwd(10**9)(params["tables"], b["indices"]))
np.testing.assert_allclose(p1, p2, rtol=1e-5, atol=1e-5)
print("MATCH")
"""


def test_chunked_lookup_matches_unchunked(subproc):
    r = subproc(CHUNKED_CASE)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MATCH" in r.stdout
