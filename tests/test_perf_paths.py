"""Coverage for the §Perf-optimized code paths: distributed MoE dispatch,
chunked recurrent scans, and the LM sharding rules."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, MoEConfig, SSMConfig
from repro.configs.registry import ARCHS


# ----------------------------------------------------------- chunked scans
@pytest.mark.parametrize("kind", ["rwkv6", "mamba"])
def test_chunked_scan_matches_plain_with_grads(kind):
    from repro.models import ssm as S

    cfg = ModelConfig(name="t", family="ssm" if kind == "rwkv6" else "hybrid",
                      n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                      d_ff=64, vocab_size=64,
                      ssm=SSMConfig(kind=kind, head_dim=16, d_state=8))
    init_p = S.init_rwkv6 if kind == "rwkv6" else S.init_mamba
    scan = S.rwkv6_scan if kind == "rwkv6" else S.mamba_scan
    p = init_p(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 96, cfg.d_model))

    old = os.environ.get("REPRO_SSM_CHUNK")
    try:
        os.environ["REPRO_SSM_CHUNK"] = "0"
        y0, _ = scan(p, x, cfg)
        g0 = jax.grad(lambda p: jnp.sum(scan(p, x, cfg)[0] ** 2))(p)
        os.environ["REPRO_SSM_CHUNK"] = "24"
        y1, _ = scan(p, x, cfg)
        g1 = jax.grad(lambda p: jnp.sum(scan(p, x, cfg)[0] ** 2))(p)
    finally:
        if old is None:
            os.environ.pop("REPRO_SSM_CHUNK", None)
        else:
            os.environ["REPRO_SSM_CHUNK"] = old
    np.testing.assert_allclose(np.asarray(y0, np.float32),
                               np.asarray(y1, np.float32), rtol=1e-4, atol=1e-4)
    for a, b in zip(jax.tree_util.tree_leaves(g0), jax.tree_util.tree_leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-3)


def test_chunked_scan_falls_back_on_odd_lengths():
    from repro.models.ssm import chunked_time_scan

    def step(c, xs):
        (x,) = xs
        return c + x, c

    xs = (jnp.arange(10.0),)
    os.environ["REPRO_SSM_CHUNK"] = "64"       # chunk > T -> plain scan
    try:
        c, ys = chunked_time_scan(step, jnp.zeros(()), xs, 10)
    finally:
        os.environ.pop("REPRO_SSM_CHUNK", None)
    assert float(c) == 45.0


# ------------------------------------------------------- distributed MoE
MOE_CASE = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import ModelConfig, MoEConfig
from repro.models.layers import init_moe, moe_block
from repro.models.moe_dist import moe_block_local_dispatch, moe_block_ep_a2a
from repro.models.common import Sharder
from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 4), ('data', 'model'))
sharder = Sharder(mesh, batch_axes=('data',), model_axes=('model',))
cfg = ModelConfig(name='t', family='moe', n_layers=2, d_model=32,
                  n_heads=4, n_kv_heads=4, d_ff=64, vocab_size=64,
                  moe=MoEConfig(num_experts={E}, top_k=2, capacity_factor=64.0))
p = init_moe(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))
ref = moe_block(p, x, cfg)
xd = jax.device_put(x, NamedSharding(mesh, P('data', None, None)))
out = jax.jit(lambda p, x: {fn}(p, x, cfg, sharder))(p, xd)
np.testing.assert_allclose(np.asarray(ref, np.float32),
                           np.asarray(out, np.float32), rtol=2e-3, atol=2e-3)
# gradient parity
gd = jax.jit(jax.grad(lambda p, x: jnp.sum({fn}(p, x, cfg, sharder)**2)))(p, xd)
gg = jax.grad(lambda p, x: jnp.sum(moe_block(p, x, cfg)**2))(p, x)
for k in gg:
    np.testing.assert_allclose(np.asarray(gd[k], np.float32),
                               np.asarray(gg[k], np.float32),
                               rtol=5e-3, atol=5e-3, err_msg=k)
print("MATCH")
"""


@pytest.mark.parametrize("fn,E", [
    ("moe_block_local_dispatch", 8),
    ("moe_block_local_dispatch", 6),
    ("moe_block_ep_a2a", 8),
])
def test_distributed_moe_matches_global(subproc, fn, E):
    r = subproc(MOE_CASE.format(fn=fn, E=E))
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MATCH" in r.stdout


# ------------------------------------------------------- sharding rules
def test_param_specs_2d_sharding():
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_host_mesh
    from repro.models import sharding_rules as rules
    from repro.models import transformer as T

    cfg = ARCHS["internlm2-1.8b"]
    params = jax.eval_shape(lambda: T.init_model(jax.random.PRNGKey(0), cfg))
    specs = rules.param_specs(cfg, params)
    flat = {rules._path_str(p): s for p, s in
            jax.tree_util.tree_flatten_with_path(
                specs, is_leaf=lambda x: isinstance(x, P))[0]}
    assert flat["embed"] == P("model", "data")
    # attention wq: stacked (U, d, Hq*hd) -> (None, data, model)
    wq = [v for k, v in flat.items() if k.endswith("wq")][0]
    assert wq == P(None, "data", "model")
    wo = [v for k, v in flat.items() if k.endswith("wo")][0]
    assert wo == P(None, "model", "data")
    # norms replicated
    n1 = [v for k, v in flat.items() if k.endswith("norm1")][0]
    assert n1 == P()


def test_filter_specs_drops_nondivisible():
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_host_mesh
    from repro.models import sharding_rules as rules

    mesh = make_host_mesh()                    # 1 device: everything drops
    specs = {"w": P("data", "model")}
    leaves = {"w": jax.ShapeDtypeStruct((7, 13), jnp.float32)}
    out = rules.filter_specs(specs, leaves, mesh)
    assert out["w"] == P(None, None)


def test_moe_impl_env_selector():
    """REPRO_MOE_IMPL=global forces the baseline path even with a mesh."""
    from repro.launch.mesh import make_host_mesh
    from repro.models.common import Sharder
    from repro.models.layers import init_moe, moe_block

    cfg = ModelConfig(name="t", family="moe", n_layers=2, d_model=16,
                      n_heads=2, n_kv_heads=2, d_ff=32, vocab_size=64,
                      moe=MoEConfig(num_experts=4, top_k=2))
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.ones((2, 3, 16))
    mesh = make_host_mesh()
    os.environ["REPRO_MOE_IMPL"] = "global"
    try:
        out = moe_block(p, x, cfg, Sharder(mesh))
    finally:
        os.environ.pop("REPRO_MOE_IMPL", None)
    assert out.shape == x.shape
