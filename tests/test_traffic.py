"""repro.traffic: scenario generation + trace record/replay.

The subsystem's load-bearing property: an event stream IS the workload —
query content is a pure function of the event — so a recorded JSONL
trace must replay bit-identically to live generation, for every
scenario. Plus the scenario-shape checks: diurnal modulates the rate,
flash_crowd bursts, zipf_drift rotates the hot-row permutation through a
row-space bijection.
"""
import dataclasses
import os

import numpy as np
import pytest

from repro.configs.registry import get_dlrm
from repro.traffic import (SCENARIOS, QueryEvent, load_trace, make_scenario,
                           materialize_query, record_trace)

SCENARIO_KW = {
    "stationary": dict(alpha=1.05),
    "diurnal": dict(alpha=1.05, amplitude=0.8, period_s=0.2),
    "flash_crowd": dict(alpha=1.05, burst_factor=6.0, on_s=0.05, off_s=0.1),
    "zipf_drift": dict(alpha=1.0, alpha_hi=1.4, drift_period_s=0.4,
                       rotate_every_s=0.06, salt_stride=37),
}


def _cfg():
    return dataclasses.replace(
        get_dlrm("dlrm-rm2-small-unsharded").reduced(), batch_size=8)


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_events_deterministic_and_well_formed(name):
    sc = make_scenario(name, **SCENARIO_KW[name])
    ev = sc.events(50, qps=200.0, seed=7)
    assert ev == sc.events(50, qps=200.0, seed=7)
    assert ev != sc.events(50, qps=200.0, seed=8)
    assert [e.qid for e in ev] == list(range(50))
    arr = [e.arrival_s for e in ev]
    assert all(b > a for a, b in zip(arr, arr[1:]))   # strictly ordered
    assert all(e.arrival_s > 0 for e in ev)


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_trace_replay_bit_identical(name, tmp_path):
    """Recorded trace == live generation, events AND materialized query
    content (the reproducibility contract of every cluster bench)."""
    cfg = _cfg()
    sc = make_scenario(name, **SCENARIO_KW[name])
    events = sc.events(30, qps=300.0, seed=3)
    path = os.path.join(tmp_path, f"{name}.jsonl")
    record_trace(path, events, sc, qps=300.0, seed=3)
    meta, loaded = load_trace(path)
    assert meta["scenario"] == name and meta["n"] == 30
    assert loaded == events                    # exact, including floats
    for ev_live, ev_rec in zip(events[::7], loaded[::7]):
        a = materialize_query(cfg, ev_live)
        b = materialize_query(cfg, ev_rec)
        assert np.array_equal(np.asarray(a["dense"]), np.asarray(b["dense"]))
        assert np.array_equal(np.asarray(a["indices"]),
                              np.asarray(b["indices"]))


def test_trace_rejects_bad_version_and_truncation(tmp_path):
    sc = make_scenario("stationary")
    events = sc.events(5, qps=100.0, seed=0)
    path = os.path.join(tmp_path, "t.jsonl")
    record_trace(path, events, sc)
    with open(path) as f:
        lines = f.read().splitlines()
    with open(path, "w") as f:                 # drop one event
        f.write("\n".join(lines[:-1]) + "\n")
    with pytest.raises(ValueError, match="truncated"):
        load_trace(path)
    with open(path, "w") as f:
        f.write('{"trace_version": 99, "n": 0}\n')
    with pytest.raises(ValueError, match="trace_version"):
        load_trace(path)


def test_diurnal_modulates_arrival_rate():
    """More arrivals land in the sin>0 half-period than in the sin<0 one."""
    sc = make_scenario("diurnal", amplitude=0.8, period_s=1.0)
    ev = sc.events(400, qps=400.0, seed=0)
    phase = [e.arrival_s % 1.0 for e in ev]
    up = sum(1 for p in phase if p < 0.5)      # rising half of the sinusoid
    down = len(phase) - up
    assert up > 1.4 * down, (up, down)


def test_flash_crowd_is_bursty():
    """Inter-arrival gaps mix a fast (burst) and a slow (base) regime: the
    squared coefficient of variation of the gaps is ~1 for a homogeneous
    Poisson process and far above it for the MMPP-style mixture."""
    kw = dict(alpha=0.0, burst_factor=8.0, on_s=0.08, off_s=0.15)
    ev = make_scenario("flash_crowd", **kw).events(400, qps=300.0, seed=0)
    gaps = np.diff([e.arrival_s for e in ev])
    cv2 = np.var(gaps) / np.mean(gaps) ** 2
    base = make_scenario("stationary").events(400, qps=300.0, seed=0)
    base_gaps = np.diff([e.arrival_s for e in base])
    base_cv2 = np.var(base_gaps) / np.mean(base_gaps) ** 2
    assert base_cv2 < 1.5, base_cv2
    assert cv2 > 2.0, (cv2, base_cv2)


def test_zipf_drift_rotates_salt_and_sweeps_alpha():
    sc = make_scenario("zipf_drift", **SCENARIO_KW["zipf_drift"])
    ev = sc.events(200, qps=500.0, seed=2)
    salts = sorted({e.perm_salt for e in ev})
    assert len(salts) >= 3 and salts[0] == 0
    assert all(s % 37 == 0 for s in salts)     # multiples of the stride
    alphas = {round(e.alpha, 6) for e in ev}
    assert len(alphas) > 10                    # alpha actually sweeps
    assert all(1.0 <= e.alpha <= 1.4 + 1e-9 for e in ev)


def test_perm_salt_is_rowspace_rotation():
    """materialize applies (idx + salt) % R — a bijection that rotates
    WHICH rows are hot without changing the distribution's shape."""
    cfg = _cfg()
    base = QueryEvent(qid=0, arrival_s=0.1, step=5, seed=0, alpha=1.1)
    rot = dataclasses.replace(base, perm_salt=37)
    i0 = np.asarray(materialize_query(cfg, base)["indices"])
    i1 = np.asarray(materialize_query(cfg, rot)["indices"])
    np.testing.assert_array_equal((i0 + 37) % cfg.rows_per_table, i1)
    # dense features are salt-independent
    d0 = np.asarray(materialize_query(cfg, base)["dense"])
    d1 = np.asarray(materialize_query(cfg, rot)["dense"])
    np.testing.assert_array_equal(d0, d1)


def test_scenario_registry_and_validation():
    with pytest.raises(ValueError, match="unknown scenario"):
        make_scenario("nosuch")
    with pytest.raises(ValueError, match="rate must be > 0"):
        make_scenario("stationary").events(5, qps=0.0)
    with pytest.raises(ValueError, match="amplitude"):
        make_scenario("diurnal", amplitude=1.5)
    with pytest.raises(ValueError, match="burst_factor"):
        make_scenario("flash_crowd", burst_factor=0.5)
