"""repro.traffic: scenario generation + trace record/replay.

The subsystem's load-bearing property: an event stream IS the workload —
query content is a pure function of the event — so a recorded JSONL
trace must replay bit-identically to live generation, for every
scenario. Plus the scenario-shape checks: diurnal modulates the rate,
flash_crowd bursts, zipf_drift rotates the hot-row permutation through a
row-space bijection.
"""
import dataclasses
import os

import numpy as np
import pytest

from repro.configs.registry import get_dlrm
from repro.traffic import (SCENARIOS, QueryEvent, load_trace, make_scenario,
                           materialize_query, record_trace)

SCENARIO_KW = {
    "stationary": dict(alpha=1.05),
    "diurnal": dict(alpha=1.05, amplitude=0.8, period_s=0.2),
    "flash_crowd": dict(alpha=1.05, burst_factor=6.0, on_s=0.05, off_s=0.1),
    "zipf_drift": dict(alpha=1.0, alpha_hi=1.4, drift_period_s=0.4,
                       rotate_every_s=0.06, salt_stride=37),
}


def _cfg():
    return dataclasses.replace(
        get_dlrm("dlrm-rm2-small-unsharded").reduced(), batch_size=8)


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_events_deterministic_and_well_formed(name):
    sc = make_scenario(name, **SCENARIO_KW[name])
    ev = sc.events(50, qps=200.0, seed=7)
    assert ev == sc.events(50, qps=200.0, seed=7)
    assert ev != sc.events(50, qps=200.0, seed=8)
    assert [e.qid for e in ev] == list(range(50))
    arr = [e.arrival_s for e in ev]
    assert all(b > a for a, b in zip(arr, arr[1:]))   # strictly ordered
    assert all(e.arrival_s > 0 for e in ev)


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_trace_replay_bit_identical(name, tmp_path):
    """Recorded trace == live generation, events AND materialized query
    content (the reproducibility contract of every cluster bench)."""
    cfg = _cfg()
    sc = make_scenario(name, **SCENARIO_KW[name])
    events = sc.events(30, qps=300.0, seed=3)
    path = os.path.join(tmp_path, f"{name}.jsonl")
    record_trace(path, events, sc, qps=300.0, seed=3)
    meta, loaded = load_trace(path)
    assert meta["scenario"] == name and meta["n"] == 30
    assert loaded == events                    # exact, including floats
    for ev_live, ev_rec in zip(events[::7], loaded[::7]):
        a = materialize_query(cfg, ev_live)
        b = materialize_query(cfg, ev_rec)
        assert np.array_equal(np.asarray(a["dense"]), np.asarray(b["dense"]))
        assert np.array_equal(np.asarray(a["indices"]),
                              np.asarray(b["indices"]))


def test_trace_rejects_bad_version_and_truncation(tmp_path):
    sc = make_scenario("stationary")
    events = sc.events(5, qps=100.0, seed=0)
    path = os.path.join(tmp_path, "t.jsonl")
    record_trace(path, events, sc)
    with open(path) as f:
        lines = f.read().splitlines()
    with open(path, "w") as f:                 # drop one event
        f.write("\n".join(lines[:-1]) + "\n")
    with pytest.raises(ValueError, match="truncated"):
        load_trace(path)
    with open(path, "w") as f:
        f.write('{"trace_version": 99, "n": 0}\n')
    with pytest.raises(ValueError, match="trace_version"):
        load_trace(path)


def test_diurnal_modulates_arrival_rate():
    """More arrivals land in the sin>0 half-period than in the sin<0 one."""
    sc = make_scenario("diurnal", amplitude=0.8, period_s=1.0)
    ev = sc.events(400, qps=400.0, seed=0)
    phase = [e.arrival_s % 1.0 for e in ev]
    up = sum(1 for p in phase if p < 0.5)      # rising half of the sinusoid
    down = len(phase) - up
    assert up > 1.4 * down, (up, down)


def test_flash_crowd_is_bursty():
    """Inter-arrival gaps mix a fast (burst) and a slow (base) regime: the
    squared coefficient of variation of the gaps is ~1 for a homogeneous
    Poisson process and far above it for the MMPP-style mixture."""
    kw = dict(alpha=0.0, burst_factor=8.0, on_s=0.08, off_s=0.15)
    ev = make_scenario("flash_crowd", **kw).events(400, qps=300.0, seed=0)
    gaps = np.diff([e.arrival_s for e in ev])
    cv2 = np.var(gaps) / np.mean(gaps) ** 2
    base = make_scenario("stationary").events(400, qps=300.0, seed=0)
    base_gaps = np.diff([e.arrival_s for e in base])
    base_cv2 = np.var(base_gaps) / np.mean(base_gaps) ** 2
    assert base_cv2 < 1.5, base_cv2
    assert cv2 > 2.0, (cv2, base_cv2)


def test_zipf_drift_rotates_salt_and_sweeps_alpha():
    sc = make_scenario("zipf_drift", **SCENARIO_KW["zipf_drift"])
    ev = sc.events(200, qps=500.0, seed=2)
    salts = sorted({e.perm_salt for e in ev})
    assert len(salts) >= 3 and salts[0] == 0
    assert all(s % 37 == 0 for s in salts)     # multiples of the stride
    alphas = {round(e.alpha, 6) for e in ev}
    assert len(alphas) > 10                    # alpha actually sweeps
    assert all(1.0 <= e.alpha <= 1.4 + 1e-9 for e in ev)


def test_perm_salt_is_rowspace_rotation():
    """materialize applies (idx + salt) % R — a bijection that rotates
    WHICH rows are hot without changing the distribution's shape."""
    cfg = _cfg()
    base = QueryEvent(qid=0, arrival_s=0.1, step=5, seed=0, alpha=1.1)
    rot = dataclasses.replace(base, perm_salt=37)
    i0 = np.asarray(materialize_query(cfg, base)["indices"])
    i1 = np.asarray(materialize_query(cfg, rot)["indices"])
    np.testing.assert_array_equal((i0 + 37) % cfg.rows_per_table, i1)
    # dense features are salt-independent
    d0 = np.asarray(materialize_query(cfg, base)["dense"])
    d1 = np.asarray(materialize_query(cfg, rot)["dense"])
    np.testing.assert_array_equal(d0, d1)


def test_scenario_registry_and_validation():
    with pytest.raises(ValueError, match="unknown scenario"):
        make_scenario("nosuch")
    with pytest.raises(ValueError, match="rate must be > 0"):
        make_scenario("stationary").events(5, qps=0.0)
    with pytest.raises(ValueError, match="amplitude"):
        make_scenario("diurnal", amplitude=1.5)
    with pytest.raises(ValueError, match="burst_factor"):
        make_scenario("flash_crowd", burst_factor=0.5)


# ---------------------------------------------------------------------------
# External-log ingestion (traffic/ingest.py)
# ---------------------------------------------------------------------------
def _write_log(path, records):
    import json
    with open(path, "w") as f:
        for r in records:
            f.write((r if isinstance(r, str) else json.dumps(r)) + "\n")


def test_ingest_round_trips_through_trace(tmp_path):
    """External log -> QueryEvents -> record_trace -> load_trace must be
    lossless: ingested streams are first-class trace citizens."""
    from repro.traffic import ingest_jsonl

    rng = np.random.default_rng(7)
    t = 1712009423.0
    recs = []
    for _ in range(40):
        t += float(rng.exponential(0.01))
        items = [int(i) for i in rng.zipf(1.5, size=5) % 500]
        recs.append({"ts": t, "items": items})
    rng.shuffle(recs)                       # out-of-order logs are fine
    log = tmp_path / "requests.jsonl"
    _write_log(log, recs)

    meta, events = ingest_jsonl(str(log), seed=3)
    assert len(events) == 40 and meta["n"] == 40
    assert events[0].arrival_s == 0.0       # normalized to t=0
    assert all(a.arrival_s <= b.arrival_s for a, b in zip(events, events[1:]))
    assert all(e.seed == 3 and e.perm_salt == 0 for e in events)
    assert meta["alpha_fitted"] and 0.0 < meta["alpha"] <= 3.0
    assert meta["qps"] == pytest.approx(40 / events[-1].arrival_s)

    trace = tmp_path / "ingested.jsonl"
    record_trace(str(trace), events, **meta)
    header, loaded = load_trace(str(trace))
    assert loaded == events                 # lossless round trip
    assert header["source"] == str(log) and header["ingested"]

    # the adapter honors an explicit alpha override
    _, ev2 = ingest_jsonl(str(log), alpha=1.05)
    assert all(e.alpha == 1.05 for e in ev2)


def test_ingest_malformed_records(tmp_path):
    from repro.traffic import IngestError, ingest_jsonl

    log = tmp_path / "bad.jsonl"
    _write_log(log, [{"ts": 1.0, "items": [1, 2]},
                     "{not json",
                     {"ts": 2.0, "items": [3]}])
    with pytest.raises(IngestError, match=r"bad\.jsonl:2: invalid JSON"):
        ingest_jsonl(str(log))
    meta, events = ingest_jsonl(str(log), strict=False)
    assert len(events) == 2 and meta["skipped"] == 1

    cases = [
        ({"items": [1]}, "missing 'ts'"),
        ({"ts": 1.0}, "missing 'items'"),
        ({"ts": "noon", "items": [1]}, "finite number"),
        ({"ts": float("nan"), "items": [1]}, "finite number"),
        ({"ts": 10 ** 400, "items": [1]}, "finite number"),  # legal JSON int
        ({"ts": 1.0, "items": []}, "non-empty list"),
        ({"ts": 1.0, "items": [1, -2]}, "non-negative"),
        ({"ts": 1.0, "items": "abc"}, "non-empty list"),
    ]
    for rec, msg in cases:
        _write_log(log, [rec])
        with pytest.raises(IngestError, match=msg):
            ingest_jsonl(str(log))
    _write_log(log, [])
    with pytest.raises(IngestError, match="no usable records"):
        ingest_jsonl(str(log))


def test_ingest_alpha_estimator_tracks_skew():
    from repro.traffic import estimate_zipf_alpha

    rng = np.random.default_rng(0)
    flat = np.bincount(rng.integers(0, 200, size=5000))
    skew = np.bincount(rng.zipf(2.0, size=5000) % 200)
    assert estimate_zipf_alpha(skew) > estimate_zipf_alpha(flat) + 0.3
    assert estimate_zipf_alpha([5]) == 0.0          # degenerate
    assert 0.0 <= estimate_zipf_alpha(flat) <= 3.0


def test_ingested_events_drive_a_cluster(tmp_path):
    """End to end: a measured log's arrival process served by the fleet."""
    from repro.cluster import Cluster
    from repro.traffic import ingest_jsonl

    log = tmp_path / "prod.jsonl"
    rng = np.random.default_rng(1)
    t = 100.0
    recs = []
    for _ in range(10):
        t += float(rng.exponential(0.004))
        recs.append({"ts": t, "items": [int(rng.integers(0, 99))]})
    _write_log(log, recs)
    _, events = ingest_jsonl(str(log), alpha=1.05)
    cfg = _cfg()
    report = Cluster(cfg, n_replicas=2, alpha=1.05, max_batch_queries=2
                     ).run(events, sla_ms=1e6, scenario="ingested")
    assert report.n_queries == 10 and report.scenario == "ingested"
