"""repro.fabric row-range sharding + live elastic re-partitioning.

The invariants this PR's layer must hold:

  * a table LARGER than any board — unservable at whole-table
    granularity — splits into row ranges and the fleet serves it
    bit-identically to a hypothetical single board big enough to hold
    it, cache on and off (THE acceptance criterion);
  * `expand_map` / `shrink_map` produce balanced covering maps;
    `plan_migration` moves exactly the changed-owner rows (bytes_moved
    is the provable floor) and prices the stall via
    `perf_model.repartition_time`;
  * `RemoteRowCache.update_ownership` invalidates ONLY rows whose
    remote-status changed — a re-partition must not cold-start the
    whole cache;
  * an `SLAAutoscaler`-driven fleet grows mid-trace under a flash
    crowd and shrinks under slack (victim = last board, drained,
    retired with a timestamp, board-seconds stop accruing) with ZERO
    output drift in either direction.
"""
import dataclasses
import os
import sys

import numpy as np
import pytest

from repro.configs.registry import get_dlrm
from repro.traffic import make_scenario

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _cfg(**kw):
    return dataclasses.replace(
        get_dlrm("dlrm-rm2-small-unsharded").reduced(), batch_size=8, **kw)


def _covers(pm):
    """Every table's [0, R) covered exactly once by pm.shards."""
    for t in range(pm.num_tables):
        ts = sorted(pm.table_shards(t), key=lambda s: s.row_lo)
        assert ts[0].row_lo == 0 and ts[-1].row_hi == pm.rows_per_table
        for a, b in zip(ts, ts[1:]):
            assert a.row_hi == b.row_lo, (t, a, b)


# ---------------------------------------------------------------------------
# Row-range partition (unit)
# ---------------------------------------------------------------------------
def test_partition_rows_splits_oversized_table():
    from repro.fabric import partition_rows, partition_tables

    cfg = _cfg(num_tables=1, rows_per_table=768)
    cap = 512 * cfg.embed_dim * 2            # table is 1.5x one board
    with pytest.raises(ValueError, match="does not fit the fleet"):
        partition_tables(cfg, np.ones(1), 2, cap)
    pm = partition_rows(cfg, np.ones(1), 2, cap)
    _covers(pm)
    assert pm.split_tables == (0,) and pm.whole_tables == ()
    assert max(pm.board_bytes) <= cap
    assert sum(pm.board_bytes) == pm.total_bytes == cfg.embedding_bytes
    # per-table owner is undefined for a split map — routing goes by row
    with pytest.raises(ValueError, match="row-range split"):
        pm.owner
    cuts, owners = pm.owner_cuts(0)
    assert cuts[0] == 0 and len(cuts) == len(owners) == 2
    assert pm.owner_of(0, 0) != pm.owner_of(0, 767)
    masks = [pm.owned_mask(b) for b in range(2)]
    assert (masks[0] ^ masks[1]).all()       # exact 2-coloring of the rows
    # the true floor: raise only when a min_shard_rows range fits nowhere
    with pytest.raises(ValueError, match="row-range split"):
        partition_rows(cfg, np.ones(1), 2, cap, min_shard_rows=600)


def test_partition_rows_per_row_freq_prices_shards():
    from repro.fabric import partition_rows

    cfg = _cfg(num_tables=1, rows_per_table=768)
    cap = 512 * cfg.embed_dim * 2
    freq = np.zeros((1, 768))
    freq[0, :100] = 1.0                      # all mass in the head
    pm = partition_rows(cfg, freq, 2, cap)
    head = pm.owner_of(0, 0)
    assert pm.board_load[head] == pytest.approx(100.0)
    other = 1 - head
    assert pm.board_load[other] == pytest.approx(0.0)


def test_shard_map_summary_warns_near_capacity():
    from repro.fabric import partition_rows

    cfg = _cfg(num_tables=1, rows_per_table=768)
    row_b = cfg.embed_dim * 2
    cap = 400 * row_b                        # peak fill 768/2/400 = 96%
    pm = partition_rows(cfg, np.ones(1), 2, cap)
    fill, board = pm.peak_fill()
    assert fill > 0.95
    with pytest.warns(RuntimeWarning, match="overflow"):
        s = pm.summary()
    assert "WARNING" in s and f"b{board}" in s
    # a comfortable map stays quiet
    import warnings as _w
    roomy = partition_rows(cfg, np.ones(1), 2, 2 * 768 * row_b)
    with _w.catch_warnings():
        _w.simplefilter("error")
        assert "WARNING" not in roomy.summary()


# ---------------------------------------------------------------------------
# Elastic transforms (unit, deterministic)
# ---------------------------------------------------------------------------
def _zipf_pm(n_boards=2, num_tables=8, cap_boards=None):
    """(cfg, per-row Zipf freq, map); capacity sized for `cap_boards`
    boards (default n_boards) so shrink tests leave survivor headroom."""
    from repro.fabric import partition_rows

    cfg = _cfg(num_tables=num_tables)
    rank = np.arange(1, cfg.rows_per_table + 1, dtype=np.float64)
    freq = np.broadcast_to(rank ** -1.05, (num_tables, cfg.rows_per_table))
    freq = freq / freq.sum()
    cap = int(np.ceil(1.25 * cfg.embedding_bytes
                      / (cap_boards or n_boards)))
    return cfg, freq, partition_rows(cfg, freq, n_boards, cap)


def test_expand_map_balances_onto_new_board():
    from repro.fabric import expand_map

    cfg, freq, pm = _zipf_pm(n_boards=2)
    grown = expand_map(pm, freq)
    _covers(grown)
    assert grown.n_boards == 3
    # the new board carries a real share and nobody is stripped bare
    total = sum(grown.board_load)
    assert grown.board_load[2] > 0.15 * total
    assert all(l > 0 for l in grown.board_load)
    assert grown.load_balance() < 1.5
    assert all(b <= pm.board_capacity_bytes for b in grown.board_bytes)
    # byte accounting still exact
    assert sum(grown.board_bytes) == pm.total_bytes


def test_shrink_map_retires_last_board_only():
    from repro.fabric import expand_map, shrink_map

    cfg, freq, pm = _zipf_pm(n_boards=3, cap_boards=2)
    shrunk = shrink_map(pm, freq)
    _covers(shrunk)
    assert shrunk.n_boards == 2
    assert all(s.board < 2 for s in shrunk.shards)
    # survivors keep every row they had: only the victim's rows moved
    from repro.fabric import plan_migration
    plan = plan_migration(pm, shrunk)
    assert all(m.src == 2 for m in plan.moves)
    assert plan.rows_moved == sum(s.n_rows for s in pm.shards_of(2))
    # and it refuses when the survivors genuinely cannot absorb the rows
    cfg1 = _cfg(num_tables=1, rows_per_table=768)
    from repro.fabric import partition_rows
    tight = partition_rows(cfg1, np.ones(1), 2, 512 * cfg1.embed_dim * 2)
    with pytest.raises(ValueError, match="cannot shrink"):
        shrink_map(tight)
    with pytest.raises(ValueError, match="1-board"):
        shrink_map(partition_rows(cfg1, np.ones(1), 1,
                                  cfg1.embedding_bytes))
    # round trip: expand then shrink lands back on 2 covering boards
    back = shrink_map(expand_map(pm, freq), freq)
    _covers(back)
    assert back.n_boards == 3 - 1 + 1 - 1 + 1 == pm.n_boards


def test_plan_migration_moves_exactly_changed_rows():
    from repro.fabric import expand_map, plan_migration
    from repro.fabric.elastic import owner_grid

    cfg, freq, pm = _zipf_pm(n_boards=2)
    grown = expand_map(pm, freq)
    plan = plan_migration(pm, grown)
    g_old, g_new = owner_grid(pm), owner_grid(grown)
    changed = int((g_old != g_new).sum())
    assert plan.rows_moved == changed > 0
    # bytes_moved == bytes of changed-owner rows, the bench's bound
    assert plan.bytes_moved == changed * cfg.embed_dim * 2
    # moves are disjoint, land where the new map says, send==recv totals
    seen = set()
    for m in plan.moves:
        for r in range(m.row_lo, m.row_hi):
            assert (m.table, r) not in seen
            seen.add((m.table, r))
            assert g_old[m.table, r] == m.src != m.dst == g_new[m.table, r]
    assert sum(plan.per_board_send_bytes) == plan.bytes_moved
    assert sum(plan.per_board_recv_bytes) == plan.bytes_moved
    # everything streams INTO the new board on an expand
    assert plan.per_board_recv_bytes[2] == plan.bytes_moved
    # identical maps -> empty plan, zero time
    from repro.core.perf_model import fabric_link
    null = plan_migration(pm, pm)
    assert null.moves == () and null.bytes_moved == 0
    assert null.time_s(fabric_link()) == 0.0
    assert "2->3 boards" in plan.summary()
    with pytest.raises(ValueError, match="different models"):
        plan_migration(pm, _zipf_pm(num_tables=4)[2])


def test_repartition_time_terms():
    from repro.core.perf_model import fabric_link, repartition_time

    link = fabric_link(2.0, 50.0)            # 2us, 50 GB/s
    # busiest endpoint (send+recv through one port) + one latency round
    t = repartition_time([1e6, 0.0], [0.0, 1e6], link)
    assert t == pytest.approx(2 * 2e-6 + 1e6 / 50e9)
    # a port both sending and receiving serializes its two streams
    assert repartition_time([1e6, 0.0], [5e5, 5e5], link) \
        == pytest.approx(2 * 2e-6 + 1.5e6 / 50e9)
    # streams at distinct endpoints overlap: busiest-port time only
    assert repartition_time([1e6, 0.0, 0.0], [0.0, 5e5, 5e5], link) \
        == pytest.approx(2 * 2e-6 + 1e6 / 50e9)
    assert repartition_time([0.0], [0.0], link) == 0.0
    with pytest.raises(ValueError):
        repartition_time([1.0], [1.0, 2.0], link)


def test_cache_update_ownership_invalidates_only_changed_rows():
    from repro.core import tiered_embedding as te
    from repro.fabric import RemoteRowCache

    cfg = _cfg()
    freq = te.measure_row_freq(cfg, alpha=1.2, seed=0, n_batches=4)
    remote = np.zeros((cfg.num_tables, cfg.rows_per_table), bool)
    remote[:4] = True
    cache = RemoteRowCache(cfg, remote, capacity_rows=64)
    cache.warm(freq)
    cached_before = cache._cached.copy()
    assert cached_before.any()

    # migration: table 0's rows become local, table 4's become remote
    new_remote = remote.copy()
    new_remote[0] = False
    new_remote[4] = True
    n = cache.update_ownership(new_remote)
    assert n == 2 * cfg.rows_per_table
    # untouched tables keep their cached rows — no fleet-wide cold start
    np.testing.assert_array_equal(cache._cached[1:4], cached_before[1:4])
    assert not cache._cached[0].any() and not cache._cached[4].any()
    assert cache.remote_tables == (1, 2, 3, 4)
    # no-op ownership change invalidates nothing
    assert cache.update_ownership(new_remote) == 0


# ---------------------------------------------------------------------------
# THE acceptance criterion: an unservable table, served bit-identically
# ---------------------------------------------------------------------------
def test_split_table_serving_bit_identical_to_full_board():
    """One table 1.5x a board's capacity: `partition_tables` proves it
    unservable at whole-table granularity, then a 2-board row-range
    fleet serves it BIT-IDENTICALLY to a single board big enough to
    hold the whole model — remote cache on and off."""
    from repro.fabric import ShardedFleet, partition_tables

    cfg = _cfg(num_tables=1, rows_per_table=768)
    cap = 512 * cfg.embed_dim * 2
    with pytest.raises(ValueError, match="does not fit the fleet"):
        partition_tables(cfg, np.ones(1), 2, cap)

    events = make_scenario("stationary", alpha=1.05).events(
        20, qps=1000.0, seed=3)
    ref = ShardedFleet(cfg, n_boards=1, alpha=1.05,
                       board_capacity_bytes=cfg.embedding_bytes,
                       max_batch_queries=2)
    ref.run(events, sla_ms=1e6)

    wire = {}
    for cache_on in (True, False):
        fleet = ShardedFleet(cfg, n_boards=2, alpha=1.05,
                             board_capacity_bytes=cap, max_batch_queries=2,
                             cache_enabled=cache_on)
        assert fleet.partition.split_tables == (0,)
        assert max(fleet.partition.board_bytes) <= cap
        for b in fleet.boards:               # the capacity claim is real
            assert b.resident_bytes(cfg.embed_dim * 2) <= cap
        r = fleet.run(events, sla_ms=1e6)
        assert not r.fits_one_board and r.bytes_per_query > 0
        wire[cache_on] = r.bytes_per_query
        for ev in events:
            got = fleet.completed[ev.qid].probs
            want = ref.completed[ev.qid].probs
            assert np.array_equal(got, want), (
                f"qid={ev.qid} cache={cache_on} "
                f"max|d|={np.max(np.abs(got - want))}")
    assert wire[True] < wire[False]          # the cache still saves wire


# ---------------------------------------------------------------------------
# Live elastic re-partitioning, end to end
# ---------------------------------------------------------------------------
def test_elastic_scale_up_bit_identical_under_flash_crowd():
    """Flash crowd drives the autoscaler: the fleet grows mid-trace via
    MigrationPlan (bytes metered = changed-owner rows exactly) and every
    served value matches the static fleet bit for bit."""
    from repro.cluster.autoscale import SLAAutoscaler
    from repro.fabric import ShardedFleet

    cfg = _cfg()
    events = make_scenario("flash_crowd", alpha=1.05).events(
        80, qps=800.0, seed=5)
    ref = ShardedFleet(cfg, n_boards=2, alpha=1.05, max_batch_queries=2)
    ref.run(events, sla_ms=1e6)

    auto = SLAAutoscaler(0.5, min_replicas=2, max_replicas=4, window=8,
                         patience=1, cooldown_s=0.005)
    fleet = ShardedFleet(cfg, n_boards=2, alpha=1.05, max_batch_queries=2,
                         autoscaler=auto)
    r = fleet.run(events, sla_ms=1e6, scenario="flash_crowd")
    assert r.migrations == len(r.scale_events) > 0, "autoscaler never fired"
    assert any(e.action == "up" for e in r.scale_events)
    assert r.n_replicas_end > r.n_replicas_start == 2
    assert r.migrated_bytes > 0 and r.migration_s > 0
    row_b = cfg.embed_dim * 2
    for e in r.scale_events:                 # minimal-movement bound
        assert e.remesh["bytes_moved"] == e.remesh["rows_moved"] * row_b
    assert r.migrated_bytes == sum(
        e.remesh["bytes_moved"] for e in r.scale_events)
    # the policy object kept the ledger the economics plots read
    assert len(auto.migration_log) == r.migrations
    assert sum(b for _, b, _ in auto.migration_log) == r.migrated_bytes
    assert "re-partitions" in r.summary()
    for ev in events:                        # zero output drift
        np.testing.assert_array_equal(
            fleet.completed[ev.qid].probs, ref.completed[ev.qid].probs,
            err_msg=f"qid={ev.qid}")


def test_elastic_scale_down_retires_board_and_saves_board_seconds():
    """Sustained slack shrinks the fleet: the LAST board drains, its rows
    re-deal to survivors, it retires with a timestamp — board-seconds
    stop accruing — and outputs still match the static fleet exactly."""
    from repro.cluster.autoscale import SLAAutoscaler
    from repro.fabric import ShardedFleet

    cfg = _cfg()
    events = make_scenario("stationary", alpha=1.05).events(
        60, qps=500.0, seed=5)
    ref = ShardedFleet(cfg, n_boards=2, alpha=1.05, max_batch_queries=2,
                       board_capacity_bytes=cfg.embedding_bytes)
    ref.run(events, sla_ms=1e6)

    auto = SLAAutoscaler(1e6, min_replicas=1, max_replicas=2, window=8,
                         patience=1, cooldown_s=0.005)
    fleet = ShardedFleet(cfg, n_boards=2, alpha=1.05, max_batch_queries=2,
                         board_capacity_bytes=cfg.embedding_bytes,
                         autoscaler=auto)
    r = fleet.run(events, sla_ms=1e6)
    assert any(e.action == "down" for e in r.scale_events)
    assert r.n_replicas_end == 1
    assert len(fleet.boards) == 1 and fleet.boards[0].rid == 0
    assert fleet._retired and fleet._retired[0].retired_at is not None
    assert r.board_seconds < 2 * r.makespan_s
    # the retired board still appears in the report's replica stats
    assert len(r.replicas) == 2
    for ev in events:
        np.testing.assert_array_equal(
            fleet.completed[ev.qid].probs, ref.completed[ev.qid].probs,
            err_msg=f"qid={ev.qid}")


# ---------------------------------------------------------------------------
# Shared report surface (satellite: one FleetReport base)
# ---------------------------------------------------------------------------
def test_fleet_report_base_is_shared():
    from repro.cluster.cluster import ClusterReport, FleetReport
    from repro.fabric import FabricReport

    assert issubclass(ClusterReport, FleetReport)
    assert issubclass(FabricReport, FleetReport)
    assert ClusterReport.tag == "cluster" and FabricReport.tag == "fabric"
    r = FleetReport(scenario="s", router="rr", n_queries=1,
                    n_replicas_start=1, n_replicas_end=1, offered_qps=1.0,
                    achieved_qps=1.0, p50_ms=1.0, p90_ms=1.0, p99_ms=1.0,
                    percentile=99.0, ppf_ms=1.0, sla_ms=50.0, ok=True,
                    mean_batch_queries=1.0, makespan_s=1.0, replicas=(),
                    predicted_qps=None, board_seconds=2.0, sla_violations=0)
    s = r.summary()
    assert "[fleet]" in s and "board-seconds" in s


def test_bench_elastic_registered():
    if REPO not in sys.path:
        sys.path.insert(0, REPO)
    from benchmarks import run as bench_run

    assert "elastic" in {name for name, _ in bench_run.SECTIONS}
