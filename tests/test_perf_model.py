"""Perf-model validation against the PAPER'S OWN numbers — the faithfulness
contract (Tables XVI/XVII, Figs. 6/9/10, Sec. VI-B message sizes)."""
import math

import pytest

from repro.configs.registry import DLRM_CONFIGS, get_dlrm
from repro.core import memsys
from repro.core.collectives import (CollectiveOp, Interconnect, Topology,
                                    collective_time)
from repro.core.perf_model import (
    PAPER_TABLE_XVI, PAPER_TABLE_XVII, breakdown, dgx2_system,
    dense_param_count, latency_sensitivity, recspeed_system, sharding_penalty,
    sweep_system)


# ---------------------------------------------------------------- Table XVI
@pytest.mark.parametrize("config", sorted(PAPER_TABLE_XVI))
def test_recspeed_inference_qps_matches_paper(config):
    """RecSpeed inference QPS within 25% of the paper's Table XVI."""
    cfg = get_dlrm(config)
    bd = breakdown(cfg, recspeed_system(), "inference")
    paper_qps = PAPER_TABLE_XVI[config][0]
    assert 0.75 * paper_qps <= bd.qps <= 1.35 * paper_qps, (bd.qps, paper_qps)


@pytest.mark.parametrize("config", sorted(PAPER_TABLE_XVI))
def test_inference_speedup_band(config):
    """RecSpeed/DGX-2 speedup within a factor-2 band of Table XVI (the paper
    itself reports upper bounds; the band checks order of magnitude + trend)."""
    cfg = get_dlrm(config)
    rs = breakdown(cfg, recspeed_system(), "inference")
    dg = breakdown(cfg, dgx2_system(), "inference")
    speedup = rs.qps / dg.qps
    paper = PAPER_TABLE_XVI[config][3]
    assert 0.5 * paper <= speedup <= 2.0 * paper, (speedup, paper)


@pytest.mark.parametrize("config", sorted(PAPER_TABLE_XVII))
def test_recspeed_training_qps_matches_paper(config):
    cfg = get_dlrm(config)
    bd = breakdown(cfg, recspeed_system(), "training")
    paper_qps = PAPER_TABLE_XVII[config][0]
    assert 0.6 * paper_qps <= bd.qps <= 1.6 * paper_qps, (bd.qps, paper_qps)


def test_memory_utilization_ordering():
    """Table XVI: large/unsharded is the most memory-bound (93%), small
    unsharded moderate (67%)."""
    rs = recspeed_system()
    large_u = breakdown(get_dlrm("dlrm-rm2-large-unsharded"), rs, "inference")
    small_u = breakdown(get_dlrm("dlrm-rm2-small-unsharded"), rs, "inference")
    assert large_u.mem_util > small_u.mem_util > 0.3
    assert large_u.mem_util > 0.8


# ----------------------------------------------------------- Fig. 9 latency
def test_latency_drop_about_5x():
    """Fig. 9: small/unsharded QPS drops ~5x from 0.5us to 10us CC latency."""
    sens = latency_sensitivity(get_dlrm("dlrm-rm2-small-unsharded"),
                               "inference", bandwidth_gbs=1000.0)
    assert 3.0 <= sens["drop"] <= 7.0, sens


# ---------------------------------------------------------- Fig. 10 sharding
def test_sharding_penalty_shrinks_with_bandwidth():
    """Fig. 10: ~3.1x penalty at 100 GB/s -> ~1.2x at 1000 GB/s (small cfg)."""
    u = get_dlrm("dlrm-rm2-small-unsharded")
    s = get_dlrm("dlrm-rm2-small-sharded")
    pen_low = sharding_penalty(u, s, 1.0, 100.0)
    pen_high = sharding_penalty(u, s, 1.0, 1000.0)
    assert pen_low > 2.0, pen_low
    assert pen_high < 1.6, pen_high
    assert pen_low > pen_high


# --------------------------------------------------- Sec. VI-B message sizes
def test_paper_message_sizes():
    """The quoted per-processor payloads: 320KB indices, 64KB pooled,
    ~5.2MB unpooled (small), ~60MB (large), ~2.4MB dense grads."""
    cfg_s = get_dlrm("dlrm-rm2-small-unsharded")
    n = 8
    b, t, l = cfg_s.batch_size, cfg_s.num_tables, cfg_s.lookups_per_table
    idx_bytes = b * t * l * 4 / n
    assert abs(idx_bytes - 320e3) / 320e3 < 0.01
    pooled = b * t * 64 / n
    assert abs(pooled - 64e3) / 64e3 < 0.01
    unpooled = b * t * l * 64 / n
    assert 4.8e6 <= unpooled <= 5.6e6          # ~5.2 MB
    cfg_l = get_dlrm("dlrm-rm2-large-sharded")
    unpooled_l = cfg_l.batch_size * t * l * 256 / n
    assert 55e6 <= unpooled_l <= 65e6          # ~60 MB
    dense = dense_param_count(cfg_s) * 4       # fp32 gradient all-reduce
    assert 1.8e6 <= dense <= 3.0e6             # ~2.4 MB


def test_flops_per_inference_matches_table_xii():
    """Table XII: ~1.40 MFLOPs (small), ~2 MFLOPs (large) per sample."""
    small = get_dlrm("dlrm-rm2-small-unsharded").flops_per_sample()
    large = get_dlrm("dlrm-rm2-large-unsharded").flops_per_sample()
    assert 1.2e6 <= small <= 1.6e6, small
    assert 1.7e6 <= large <= 2.4e6, large


# ------------------------------------------------------------- Fig. 6 memsys
def test_ddr4_much_slower_than_hbm_for_small_embeddings():
    """Fig. 6: server DDR4 far below HBM for 64B random reads."""
    ddr = memsys.xeon_ddr4_6ch().random_access_bytes_per_s(64)
    hbm = memsys.recspeed_hbm2e().random_access_bytes_per_s(64)
    assert hbm / ddr > 5.0, (ddr, hbm)


def test_random_access_below_streaming():
    for system in (memsys.xeon_ddr4_6ch(), memsys.v100_hbm2(),
                   memsys.gddr6_tu102()):
        r = system.random_access_bytes_per_s(64)
        assert r < system.peak_stream_bytes_per_s


def test_larger_accesses_higher_effective_bw():
    sys_ = memsys.recspeed_hbm2e()
    assert (sys_.random_access_bytes_per_s(256)
            > sys_.random_access_bytes_per_s(64))


# --------------------------------------------------------------- collectives
def test_collective_lower_bounds():
    link = Interconnect(100e9, 1e-6, Topology.QUADRATIC)
    n = 8
    v = 1e6
    a2a = collective_time(CollectiveOp.ALL_TO_ALL, v, n, link)
    ar = collective_time(CollectiveOp.ALL_REDUCE, v, n, link)
    rs = collective_time(CollectiveOp.REDUCE_SCATTER, v, n, link)
    ag = collective_time(CollectiveOp.ALL_GATHER, v, n, link)
    assert abs(a2a.wire_bytes - v * (n - 1) / n) < 1
    assert abs(ar.wire_bytes - 2 * v * (n - 1) / n) < 1
    # all-reduce == reduce-scatter + all-gather (paper Sec. IV-B)
    assert abs(ar.wire_bytes - (rs.wire_bytes + ag.wire_bytes)) < 1


def test_ring_all_to_all_worse_than_quadratic():
    """Paper [10]: quadratic beats ring by 2.3-15x for all-to-all."""
    quad = Interconnect(100e9, 1e-6, Topology.QUADRATIC)
    ring = Interconnect(100e9, 1e-6, Topology.RING)
    n = 8
    tq = collective_time(CollectiveOp.ALL_TO_ALL, 10e6, n, quad).total_s
    tr = collective_time(CollectiveOp.ALL_TO_ALL, 10e6, n, ring).total_s
    assert 1.5 <= tr / tq <= 16.0


def test_dgx2_allreduce_efficiency():
    """Paper Sec. IV-D-1: DGX-2 hits ~118GB/s all-reduce bw == ~79% of the
    150GB/s per-chip peak; in our model the bound is exactly BW/2 per
    direction-pair convention: check the rule-of-thumb ordering."""
    sys_ = dgx2_system()
    v = 100e6
    t = collective_time(CollectiveOp.ALL_REDUCE, v, 16, sys_.allreduce)
    eff_bw = 2 * v * (15 / 16) / t.total_s
    assert eff_bw <= 150e9
    assert eff_bw > 100e9
