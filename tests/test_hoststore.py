"""repro.hoststore: the pinned-host chunked embedding tier.

The invariants the subsystem must hold:

  * ChunkParamMgr: every requested row is resident after ensure(), hits
    and faults are accounted exactly, eviction writes dirty chunks back
    before reuse, flush() round-trips every update, and the step-level
    pin keeps a whole batch's working set resident simultaneously;
  * the swap scheduler slices micro-batches exactly like the parallel
    step and exposes only the un-hidable stall at depth > 1;
  * forward pooling and the split SGD scatter are BIT-IDENTICAL to the
    all-in-device reference (`dlrm_lib.embedding_bag` + per-table
    scatter-add);
  * THE hoststore equivalence invariant (subprocess): a model ~1.6x too
    big for the device budget, served through Engine.serve_session(),
    returns bit-identical outputs to the unconstrained reference on a
    recorded zipf_drift trace — cold cache and warm; training round-trips
    dirty chunks exactly (post-train host weights == reference weights);
  * calibration artifacts load, validate, and override the host link and
    the monitor's service multiplier;
  * the perf-model terms behave (swap time scaling, query-bound
    monotonicity in link bandwidth, feasible chunk-size choice);
  * the bench is registered in benchmarks/run.py.
"""
import dataclasses
import json
import os
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.registry import get_dlrm

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _cfg(**kw):
    return dataclasses.replace(
        get_dlrm("dlrm-rm2-small-unsharded").reduced(), batch_size=8, **kw)


def _tables(t=2, r=13, d=3, seed=0):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(t, r, d)).astype(np.float32)


# ---------------------------------------------------------------------------
# ChunkParamMgr (unit)
# ---------------------------------------------------------------------------
def test_chunk_coverage_exact_and_disjoint():
    from repro.hoststore import ChunkParamMgr

    for chunk_rows in (1, 2, 4, 5, 13):
        mgr = ChunkParamMgr(_tables(), chunk_rows, 4)
        seen = np.zeros((mgr.T, mgr.R), int)
        for c in range(mgr.n_chunks):
            t, lo, hi = mgr.chunk_range(c)
            assert 0 < hi - lo <= chunk_rows
            seen[t, lo:hi] += 1
        # every (table, row) covered by EXACTLY one chunk
        assert (seen == 1).all()
        # chunk_of agrees with chunk_range
        for t in range(mgr.T):
            for r in range(mgr.R):
                c = int(mgr.chunk_of(t, r))
                ct, lo, hi = mgr.chunk_range(c)
                assert ct == t and lo <= r < hi


def test_ensure_makes_rows_resident_and_accounts():
    from repro.hoststore import ChunkParamMgr

    tables = _tables()
    mgr = ChunkParamMgr(tables, 2, 4)
    st = mgr.ensure(np.array([0, 0, 1]), np.array([0, 1, 5]))
    # rows 0,1 share one chunk; row 5 of table 1 is another
    assert st.needed_chunks == 2 and st.faulted_chunks == 2
    assert st.hit_chunks == 0 and st.requested_rows == 3
    assert st.bytes_in == 2 * mgr.chunk_bytes and st.bytes_out == 0
    assert mgr.is_resident(np.array([0, 0, 1]), np.array([0, 1, 5])).all()
    # the device cache holds the right values at the mapped positions
    cache = np.asarray(mgr.device_cache)
    pos = mgr.host_pos
    for t, r in [(0, 0), (0, 1), (1, 5)]:
        assert np.array_equal(cache[pos[t, r]], tables[t, r])
    # repeat: pure hit, no traffic
    st2 = mgr.ensure(np.array([0]), np.array([1]))
    assert st2.hit_chunks == 1 and st2.faulted_chunks == 0
    assert st2.bytes_moved == 0
    # pad row stays zero, non-resident rows map to pad
    assert not cache[mgr.pad_pos].any()
    assert pos[1, 12] == mgr.pad_pos


def test_ensure_rejects_oversized_request_and_validates():
    from repro.hoststore import ChunkParamMgr

    mgr = ChunkParamMgr(_tables(), 1, 3)
    with pytest.raises(ValueError, match="chunk cache"):
        mgr.ensure(np.zeros(4, int), np.arange(4))
    with pytest.raises(ValueError):
        ChunkParamMgr(_tables(), 0, 4)
    with pytest.raises(ValueError):
        ChunkParamMgr(_tables(), 2, 0)
    with pytest.raises(ValueError):
        ChunkParamMgr(_tables(), 2, 4, policy="rand")
    with pytest.raises(ValueError):
        mgr.attach_cache(jnp.zeros((2, 2)))


def test_eviction_writes_dirty_chunks_back():
    from repro.hoststore import ChunkParamMgr

    tables = _tables()
    for policy in ("clock", "lfu"):
        mgr = ChunkParamMgr(tables, 2, 2, policy=policy)
        mgr.ensure(np.array([0, 0]), np.array([0, 2]))       # chunks 0, 1
        # simulate a device update to row (0, 0) then mark its chunk dirty
        pos = mgr.host_pos
        mgr.device_cache = mgr.device_cache.at[pos[0, 0]].add(1.0)
        mgr.mark_dirty(np.array([0]), np.array([0]))
        assert len(mgr.dirty_chunks) == 1
        # force both slots to turn over -> the dirty chunk writes back
        st = mgr.ensure(np.array([1, 1]), np.array([0, 2]))
        assert st.evicted_chunks == 2 and st.writebacks == 1
        assert st.bytes_out == mgr.chunk_bytes
        assert np.array_equal(mgr.host[0, 0], tables[0, 0] + 1.0)
        assert mgr.dirty_chunks.size == 0
        # un-dirtied neighbor row came back untouched
        assert np.array_equal(mgr.host[0, 1], tables[0, 1])


def test_mark_dirty_requires_residency():
    from repro.hoststore import ChunkParamMgr

    mgr = ChunkParamMgr(_tables(), 2, 4)
    with pytest.raises(ValueError, match="non-resident"):
        mgr.mark_dirty(np.array([0]), np.array([0]))


def test_flush_round_trips_all_dirty_chunks():
    from repro.hoststore import ChunkParamMgr

    tables = _tables()
    mgr = ChunkParamMgr(tables, 3, 4)
    mgr.ensure(np.array([0, 1, 1]), np.array([1, 4, 9]))
    pos = mgr.host_pos
    for t, r in [(0, 1), (1, 4), (1, 9)]:
        mgr.device_cache = mgr.device_cache.at[pos[t, r]].add(float(t + r))
    mgr.mark_dirty(np.array([0, 1, 1]), np.array([1, 4, 9]))
    flushed = mgr.flush()
    expect = tables.copy()
    for t, r in [(0, 1), (1, 4), (1, 9)]:
        expect[t, r] += np.float32(t + r)
    assert np.array_equal(flushed, expect)
    assert np.array_equal(mgr.host, expect)
    assert mgr.dirty_chunks.size == 0


def test_pin_excludes_victims_and_raises_when_everything_pinned():
    from repro.hoststore import ChunkParamMgr

    mgr = ChunkParamMgr(_tables(), 1, 2)
    mgr.ensure(np.array([0, 0]), np.array([0, 1]))           # chunks 0, 1
    pinned = np.array([0, 1], np.int64)
    with pytest.raises(ValueError, match="too small"):
        mgr.ensure(np.array([0]), np.array([5]), pin=pinned)
    # pinning only chunk 0 forces chunk 1 out
    mgr.ensure(np.array([0]), np.array([5]), pin=np.array([0], np.int64))
    assert mgr.is_resident(np.array([0, 0]), np.array([0, 5])).all()
    assert not mgr.is_resident(np.array([0]), np.array([1])).all()


# ---------------------------------------------------------------------------
# swap scheduler (unit)
# ---------------------------------------------------------------------------
def test_micro_batch_indices_mirror_step_slicing():
    from repro.hoststore import micro_batch_indices

    idx = np.arange(8 * 2 * 3).reshape(8, 2, 3)
    mbs = micro_batch_indices(idx, 4)
    assert len(mbs) == 4 and all(m.shape == (2, 2, 3) for m in mbs)
    assert np.array_equal(np.concatenate(mbs), idx)
    # indivisible depth or depth 1: one slice, exactly the step's batch
    assert len(micro_batch_indices(idx, 3)) == 1
    assert len(micro_batch_indices(idx, 1)) == 1


def test_plan_swaps_pins_step_working_set():
    from repro.core import perf_model
    from repro.hoststore import ChunkParamMgr, plan_swaps

    tables = _tables(t=1, r=32, d=2)
    link = perf_model.host_link()
    # working set of the whole batch (8 chunks) exceeds the cache -> the
    # step can never execute on one snapshot; plan_swaps must say so
    mgr = ChunkParamMgr(tables, 2, 6)
    idx = np.arange(16).reshape(8, 1, 2)
    with pytest.raises(ValueError, match="working set"):
        plan_swaps(mgr, idx, 4, link)
    # with room, every micro-batch's rows stay resident through the LAST
    # ensure — no earlier slice's chunk was evicted for a later slice
    mgr = ChunkParamMgr(tables, 2, 8)
    plan = plan_swaps(mgr, idx, 4, link)
    assert len(plan.stats) == 4
    t_all = np.zeros_like(idx)
    assert mgr.is_resident(t_all.ravel(), idx.ravel()).all()
    assert plan.faulted_chunks == 8
    assert plan.total_swap_s > 0


def test_overlap_stall_hides_behind_compute():
    from repro.hoststore import overlap_stall

    # depth 1: everything serializes
    assert overlap_stall([0.3], 1.0, 1) == pytest.approx(0.3)
    # depth 4, generous compute windows: only slice 0's swap is exposed
    assert overlap_stall([0.1, 0.1, 0.1, 0.1], 4.0, 4) == pytest.approx(0.1)
    # tight windows: the overflow beyond service/k is exposed too
    stall = overlap_stall([0.2, 0.2, 0.2, 0.2], 0.4, 4)
    assert stall == pytest.approx(0.2 + 3 * (0.2 - 0.1))
    assert overlap_stall([], 1.0, 4) == 0.0


# ---------------------------------------------------------------------------
# exchange: bit-identical pooling + split scatter (unit, single device)
# ---------------------------------------------------------------------------
def test_forward_and_sparse_apply_bit_identical_to_reference():
    from repro.core import dlrm as dlrm_lib
    from repro.hoststore import build_host_exchange
    from repro.parallel.updates import sgd_row_update

    cfg = _cfg()
    tables = np.asarray(
        dlrm_lib.init_dlrm(jax.random.PRNGKey(0), cfg)["tables"])
    actual = tables.size * tables.itemsize
    ex = build_host_exchange(cfg, device_capacity_bytes=int(actual / 1.6),
                             tables=tables, chunk_rows=2, hot_fraction=0.25,
                             alpha=1.05)
    rng = np.random.default_rng(1)
    idx = rng.integers(0, cfg.rows_per_table,
                       (cfg.batch_size, cfg.num_tables,
                        cfg.lookups_per_table)).astype(np.int32)
    t_of = np.broadcast_to(
        np.arange(cfg.num_tables)[None, :, None], idx.shape)
    ex.mgr.ensure(t_of.ravel(), idx.ravel())
    tbl = {"hs_hot": jnp.asarray(ex._hot_init),
           "hs_cache": ex.mgr.device_cache,
           "hs_hot_map": jnp.asarray(ex._hot_map_np),
           "hs_pos": ex.mgr.device_pos}
    pooled, ctx = jax.jit(ex.forward)(tbl, jnp.asarray(idx))
    ref = dlrm_lib.embedding_bag(jnp.asarray(tables), jnp.asarray(idx))
    assert np.array_equal(np.asarray(pooled), np.asarray(ref))

    # split SGD scatter == the reference per-table scatter, bitwise
    lr = 0.05
    upd = sgd_row_update(lr)
    g = jnp.asarray(rng.normal(
        size=(cfg.batch_size, cfg.num_tables,
              cfg.embed_dim)).astype(np.float32))
    new = jax.jit(lambda tb, c, gg: ex.sparse_apply(tb, c, gg, upd))(
        tbl, ctx, g)
    flat_idx = jnp.asarray(idx).transpose(1, 0, 2).reshape(
        cfg.num_tables, -1)
    flat_g = jnp.broadcast_to(
        g[:, :, None, :], (*idx.shape, cfg.embed_dim)
    ).transpose(1, 0, 2, 3).reshape(cfg.num_tables, -1, cfg.embed_dim)
    ref_new = np.asarray(upd(jnp.asarray(tables), flat_idx, flat_g))
    # reassemble the tiered result back into (T, R, d)
    got = ex.mgr.host.copy()
    cache = np.asarray(new["hs_cache"])
    pos = ex.mgr.host_pos
    res = pos < ex.mgr.pad_pos
    got[res] = cache[pos[res]]
    slab = np.asarray(new["hs_hot"])
    for t in range(cfg.num_tables):
        got[t, ex._hot_rows[t]] = slab[t, :ex.hot_slots]
    touched = np.zeros((cfg.num_tables, cfg.rows_per_table), bool)
    touched[t_of.ravel(), idx.ravel()] = True
    assert np.array_equal(got[touched], ref_new[touched])
    # pads stayed zero
    assert not np.asarray(new["hs_cache"])[-1].any()
    assert not np.asarray(new["hs_hot"])[:, -1].any()


def test_build_host_exchange_sizing_and_validation():
    from repro.hoststore import build_host_exchange

    cfg = _cfg()
    actual = (cfg.num_tables * cfg.rows_per_table * cfg.embed_dim
              * np.dtype(np.float32).itemsize)
    ex = build_host_exchange(cfg, device_capacity_bytes=int(actual / 1.6),
                             hot_fraction=0.25, chunk_rows=2)
    row_b = cfg.embed_dim * 4
    device_bytes = (ex.hot_slots * cfg.num_tables * row_b
                    + ex.mgr.cache_slots * ex.mgr.chunk_bytes)
    assert device_bytes <= actual / 1.6          # fits the budget
    assert ex.mgr.cache_slots >= 1 and ex.hot_slots >= 1
    with pytest.raises(ValueError):
        build_host_exchange(cfg, device_capacity_bytes=0)
    with pytest.raises(ValueError):
        build_host_exchange(cfg, device_capacity_bytes=1024,
                            hot_fraction=1.0)


# ---------------------------------------------------------------------------
# calibration artifacts
# ---------------------------------------------------------------------------
def test_calibration_loader_and_service_multiplier(tmp_path):
    from repro.core.calibration import (load_calibration,
                                        service_multiplier_from)

    art = {"host_link": {"latency_us": 3.0, "bandwidth_gbs": 12.0},
           "service_multiplier": {"hit_ratio": [0.0, 0.5, 1.0],
                                  "multiplier": [3.0, 2.0, 1.0]}}
    path = tmp_path / "calib.json"
    path.write_text(json.dumps(art))
    assert load_calibration(art) is art
    assert load_calibration(str(path)) == art

    f = service_multiplier_from(str(path))
    assert f(0.0) == pytest.approx(3.0)
    assert f(0.25) == pytest.approx(2.5)
    assert f(1.0) == pytest.approx(1.0)
    assert f(2.0) == pytest.approx(1.0)          # flat beyond endpoints
    assert service_multiplier_from(
        {"service_multiplier": 1.7})(0.3) == pytest.approx(1.7)
    with pytest.raises(ValueError, match="service_multiplier"):
        service_multiplier_from({"host_link": {}})
    with pytest.raises(ValueError, match="increasing"):
        service_multiplier_from({"service_multiplier": {
            "hit_ratio": [0.5, 0.5], "multiplier": [1.0, 2.0]}})
    with pytest.raises(ValueError):
        service_multiplier_from({"service_multiplier": {
            "hit_ratio": [0.5], "multiplier": [1.0]}})
    bad = tmp_path / "bad.json"
    bad.write_text("[1, 2]")
    with pytest.raises(ValueError, match="JSON object"):
        load_calibration(str(bad))


def test_host_link_accepts_calibration(tmp_path):
    from repro.core import perf_model

    art = {"host_link": {"latency_us": 3.0, "bandwidth_gbs": 12.0}}
    path = tmp_path / "calib.json"
    path.write_text(json.dumps(art))
    link = perf_model.host_link(calibration=str(path))
    assert link.latency == pytest.approx(3.0e-6)
    assert link.bandwidth == pytest.approx(12.0e9)
    # partial artifact: only the provided field overrides
    part = perf_model.host_link(
        latency_us=7.0, calibration={"host_link": {"bandwidth_gbs": 20.0}})
    assert part.latency == pytest.approx(7.0e-6)
    assert part.bandwidth == pytest.approx(20.0e9)
    # no host_link entry: defaults survive
    dflt = perf_model.host_link(calibration={})
    assert dflt.bandwidth == pytest.approx(16.0e9)


def test_monitor_accepts_calibration_path(tmp_path):
    from repro.cluster import HitRatioMonitor

    art = {"service_multiplier": {"hit_ratio": [0.0, 1.0],
                                  "multiplier": [4.0, 1.0]}}
    path = tmp_path / "calib.json"
    path.write_text(json.dumps(art))
    mon = HitRatioMonitor(_cfg(), service_multiplier=str(path))
    assert mon.service_multiplier(0.0) == pytest.approx(4.0)
    assert mon.service_multiplier(1.0) == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# perf model terms
# ---------------------------------------------------------------------------
def test_host_swap_time_scaling():
    from repro.core import perf_model

    link = perf_model.host_link(latency_us=10.0, bandwidth_gbs=10.0)
    assert perf_model.host_swap_time(0, link) == 0.0
    one = perf_model.host_swap_time(1e6, link, n_transfers=1)
    assert one == pytest.approx(10e-6 + 1e6 / 10e9)
    # more DMA descriptors for the same bytes cost more
    assert perf_model.host_swap_time(1e6, link, n_transfers=8) > one


def test_hoststore_query_bound_monotone_in_bandwidth_and_hit_ratio():
    from repro.core import perf_model

    cfg = _cfg()
    sys_ = perf_model.recspeed_system()
    t_steps = [perf_model.hoststore_query_bound(
        cfg, sys_, perf_model.host_link(bandwidth_gbs=g),
        device_hit_ratio=0.5, chunk_rows=4, pipeline_depth=2).t_step
        for g in (8.0, 16.0, 32.0, 64.0)]
    assert t_steps[0] > t_steps[1] > t_steps[2] > t_steps[3]
    # a better device hit ratio can only help
    lo = perf_model.hoststore_query_bound(
        cfg, sys_, perf_model.host_link(), 0.2, 4, pipeline_depth=2)
    hi = perf_model.hoststore_query_bound(
        cfg, sys_, perf_model.host_link(), 0.9, 4, pipeline_depth=2)
    assert hi.t_step < lo.t_step
    assert "t_host_swap" in lo.notes


def test_choose_hoststore_config_feasible():
    from repro.core import perf_model

    cfg = _cfg()
    link = perf_model.host_link()
    row_b = cfg.embed_dim * perf_model.recspeed_system().elem_bytes
    best, sweep = perf_model.choose_hoststore_config(
        cfg, link, cache_budget_bytes=256 * row_b)
    assert best >= 1
    if sweep:
        # the pick is the argmin of the swept step times
        assert sweep[best] == min(sweep.values())
        assert all(
            cr * row_b * 1 <= 256 * row_b for cr in sweep)   # grid sane


# ---------------------------------------------------------------------------
# THE equivalence invariants (subprocess: real Engine sessions)
# ---------------------------------------------------------------------------
SERVE_EQUIVALENCE = r"""
import dataclasses
import numpy as np
from repro.configs.registry import get_dlrm
from repro.engine import Engine
from repro.traffic import load_trace, make_scenario, materialize_query, \
    record_trace

cfg = dataclasses.replace(get_dlrm("dlrm-rm2-small-unsharded").reduced(),
                          batch_size=8)
actual = cfg.num_tables * cfg.rows_per_table * cfg.embed_dim * 4
cap_mb = (actual / 1.6) / 2 ** 20          # tables are 1.6x over budget
assert actual > 1.5 * cap_mb * 2 ** 20

DEPTH = 4
scenario = make_scenario("zipf_drift", alpha=1.05)
events = scenario.events(24, qps=500.0, seed=0)
record_trace("/tmp/hoststore_drift.jsonl", events, scenario, qps=500.0,
             seed=0)
_, events = load_trace("/tmp/hoststore_drift.jsonl")

# pipeline depth changes MLP micro-batch shapes (1-ulp matmul tiling), so
# the reference runs at the SAME depth as the host-tiered session
ref = Engine(cfg, model_axis=1, pipeline_depth=DEPTH).serve_session(
    max_batch_queries=1)
host = Engine(cfg, model_axis=1, pipeline_depth=DEPTH,
              host_capacity_mb=cap_mb, host_hot_fraction=0.25,
              host_chunk_rows=1).serve_session(max_batch_queries=1)
ex = host._exchange_inst

for phase in ("cold", "warm"):
    faults = 0
    for ev in events:
        q = materialize_query(cfg, ev)
        p_ref, _, _ = ref._execute([q])
        p_host, _, _ = host._execute([q])
        assert np.array_equal(p_ref, p_host), \
            f"{phase}: qid {ev.qid} diverged"
        faults += ex._last_plan.faulted_chunks
    print(f"{phase}: {faults} chunk faults")
    if phase == "cold":
        cold_faults = faults
assert faults < cold_faults, "warm replay should fault less than cold"
print("OK")
"""


def test_host_tier_serving_bit_identical_over_budget(subproc):
    r = subproc(SERVE_EQUIVALENCE, n_devices=1, timeout=600)
    assert r.returncode == 0, r.stderr[-4000:]
    assert "OK" in r.stdout


TRAIN_ROUND_TRIP = r"""
import dataclasses
import numpy as np
import jax
from repro.configs.registry import get_dlrm
from repro.engine import Engine

cfg = dataclasses.replace(get_dlrm("dlrm-rm2-small-unsharded").reduced(),
                          batch_size=8)
actual = cfg.num_tables * cfg.rows_per_table * cfg.embed_dim * 4
cap_mb = (actual / 1.6) / 2 ** 20
DEPTH, STEPS, LR = 4, 6, 0.05

ref = Engine(cfg, model_axis=1, lr=LR,
             pipeline_depth=DEPTH).train_session()
rep_r = ref.run(STEPS)
ref_tables = np.asarray(jax.device_get(ref.params["tables"]))

host = Engine(cfg, model_axis=1, lr=LR, pipeline_depth=DEPTH,
              host_capacity_mb=cap_mb, host_hot_fraction=0.25,
              host_chunk_rows=2).train_session()
rep_h = host.run(STEPS)
host_tables = host.exchange_inst.flush_host_weights()

assert np.array_equal(ref_tables, host_tables), \
    f"maxdiff {np.abs(ref_tables - host_tables).max()}"
# the MLPs trained identically too (same losses, same weights)
for k in ("bot_mlp", "top_mlp"):
    for a, b in zip(jax.tree_util.tree_leaves(ref.params[k]),
                    jax.tree_util.tree_leaves(host.params[k])):
        assert np.array_equal(np.asarray(jax.device_get(a)),
                              np.asarray(jax.device_get(b)))
losses_r = [float(h["loss"]) for h in rep_r.history]
losses_h = [float(h["loss"]) for h in rep_h.history]
assert losses_r == losses_h
print("OK")
"""


def test_host_tier_training_round_trips_dirty_chunks(subproc):
    r = subproc(TRAIN_ROUND_TRIP, n_devices=1, timeout=600)
    assert r.returncode == 0, r.stderr[-4000:]
    assert "OK" in r.stdout


def test_bench_hoststore_registered():
    if REPO not in sys.path:
        sys.path.insert(0, REPO)
    from benchmarks import run as bench_run

    names = {name for name, _ in bench_run.SECTIONS}
    assert "hoststore" in names
    assert "hoststore" in bench_run.EMITS_JSON
