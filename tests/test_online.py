"""repro.online: continuous training streamed into the live serving fleet.

The invariants the subsystem must hold:

  * the delta channel is versioned, time-ordered, and replays bit-exactly
    through its JSONL record/load round trip;
  * `diff_tables` is an exact bitwise delta encoder — unchanged rows ship
    nothing;
  * the trainer and source are deterministic in (seed, schedule, salt),
    so two runs (or two fleet sizes) consume identical update streams;
  * the coherence protocol keeps every copy honest in both modes: a
    `RemoteRowCache` / tiered fast slab / hoststore device chunk copy is
    bit-equal to the owner's latest row or gone;
  * THE online invariant (property-tested): with random row pushes and
    lookups interleaved across a 2-board fabric, every served query is
    bit-identical to the 1-board online reference, every served row is
    bit-equal to the owner's latest visible version, and the 7-component
    latency attribution (incl. update_stall) closes exactly;
  * the cluster broadcasts batches to every replica bit-identically;
  * per-run `metrics=` registries scope serving meters (no cross-run
    contamination of the process-wide singleton);
  * the bench is registered in benchmarks/run.py with a JSON receipt.
"""
import dataclasses
import os
import sys

import numpy as np
import pytest

from repro.configs.registry import get_dlrm
from repro.traffic import make_scenario

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _cfg(**kw):
    return dataclasses.replace(
        get_dlrm("dlrm-rm2-small-unsharded").reduced(), batch_size=8, **kw)


def _rand_batch(cfg, seed, version, t_emit):
    """A deterministic pseudo-random DeltaBatch: a few tables, a few rows
    each, fresh float32 payloads."""
    from repro.online import DeltaBatch, RowDelta

    rng = np.random.default_rng(seed)
    T, R, d = cfg.num_tables, cfg.rows_per_table, cfg.embed_dim
    n_t = int(rng.integers(1, min(4, T) + 1))
    deltas = []
    for t in sorted(rng.choice(T, size=n_t, replace=False).tolist()):
        rows = np.unique(rng.integers(0, R, size=int(rng.integers(1, 17))))
        vals = rng.standard_normal((len(rows), d)).astype(np.float32)
        deltas.append(RowDelta(table=int(t), rows=rows, values=vals))
    return DeltaBatch(version=int(version), t_emit_s=float(t_emit),
                      step=int(version), deltas=tuple(deltas))


def _apply(base, batches):
    """Reference application of batches to a (T, R, d) snapshot, in
    (t_emit, version) order — what the fleet's host canonical must equal
    after a run that consumed them all."""
    out = np.array(base, copy=True)
    for b in sorted(batches, key=lambda x: (x.t_emit_s, x.version)):
        for d in b.deltas:
            out[d.table, d.rows] = d.values
    return out


def _closure_residual(records):
    from repro.obs.attribution import COMPONENTS

    return max(abs(sum(getattr(rec, c + "_s") for c in COMPONENTS)
                   - rec.latency_s) for rec in records)


# ---------------------------------------------------------------------------
# Delta encoding + channel (unit)
# ---------------------------------------------------------------------------
def test_row_delta_validation_and_wire_bytes():
    from repro.online import DeltaBatch, RowDelta
    from repro.online.delta import ELEM_BYTES, INDEX_BYTES

    d = 16
    rd = RowDelta(table=2, rows=np.array([3, 7]),
                  values=np.zeros((2, d), np.float32))
    assert rd.n_rows == 2
    assert rd.payload_bytes() == 2 * (INDEX_BYTES + d * ELEM_BYTES)
    with pytest.raises(ValueError, match="rows"):
        RowDelta(table=0, rows=np.array([1, 2, 3]),
                 values=np.zeros((2, d), np.float32))
    b = DeltaBatch(version=1, t_emit_s=0.5, step=10,
                   deltas=(rd, RowDelta(table=5, rows=np.array([0]),
                                        values=np.ones((1, d), np.float32))))
    assert b.n_rows == 3 and b.tables == (2, 5)
    assert b.payload_bytes() == 3 * (INDEX_BYTES + d * ELEM_BYTES)


def test_diff_tables_is_exact():
    from repro.online import diff_tables

    rng = np.random.default_rng(0)
    old = rng.standard_normal((3, 32, 8)).astype(np.float32)
    new = old.copy()
    new[0, 5] += 1.0
    new[2, [1, 30]] = 0.0
    batch = diff_tables(old, new, version=4, t_emit_s=1.25, step=99)
    assert batch.version == 4 and batch.step == 99
    assert batch.tables == (0, 2)
    by_table = {d.table: d for d in batch.deltas}
    assert by_table[0].rows.tolist() == [5]
    assert by_table[2].rows.tolist() == [1, 30]
    # payloads are the NEW rows, bitwise
    assert np.array_equal(by_table[0].values, new[0, [5]])
    # applying the diff reconstructs `new` exactly; untouched rows never ship
    assert np.array_equal(_apply(old, [batch]), new)
    assert diff_tables(old, old, version=1, t_emit_s=0.0).n_rows == 0
    with pytest.raises(ValueError, match="shapes differ"):
        diff_tables(old, old[:2], version=1, t_emit_s=0.0)


def test_delta_channel_order_record_replay(tmp_path):
    from repro.online import DeltaChannel

    cfg = _cfg()
    batches = [_rand_batch(cfg, s, v, t)
               for s, v, t in [(1, 1, 0.1), (2, 2, 0.3), (3, 3, 0.7)]]
    ch = DeltaChannel(batches[:2])
    assert len(ch) == 2 and ch.next_time() == 0.1
    assert [b.version for b in ch.poll(0.3)] == [1, 2]
    assert ch.next_time() is None and ch.poll(10.0) == []
    ch.push(batches[2])
    assert ch.next_time() == 0.7
    with pytest.raises(ValueError, match="time-ordered"):
        ch.push(_rand_batch(cfg, 4, 4, 0.2))
    # record captures drained AND pending batches; load round-trips bitwise
    path = str(tmp_path / "deltas.jsonl")
    assert ch.record(path) == 3
    re = DeltaChannel.load(path)
    assert len(re) == 3
    for a, b in zip(ch.emitted, re.emitted):
        assert (a.version, a.t_emit_s, a.step) == (b.version, b.t_emit_s,
                                                   b.step)
        for da, db in zip(a.deltas, b.deltas):
            assert da.table == db.table
            assert np.array_equal(da.rows, db.rows)
            assert np.array_equal(da.values, db.values)


# ---------------------------------------------------------------------------
# Trainer + source (deterministic stream)
# ---------------------------------------------------------------------------
def test_trainer_determinism_and_source_schedule():
    import jax

    from repro.core.dlrm import init_dlrm
    from repro.online import OnlineSource, OnlineTrainer

    cfg = _cfg()
    params = init_dlrm(jax.random.PRNGKey(0), cfg)

    def mk():
        return OnlineTrainer(cfg, params, lr=0.5, seed=0, alpha=1.05,
                             batch_size=16)

    t1, t2 = mk(), mk()
    l1 = t1.train_steps(3, salt=5)
    l2 = t2.train_steps(3, salt=5)
    assert l1 == l2
    assert np.array_equal(t1.tables, t2.tables)
    assert not np.array_equal(t1.tables, np.asarray(params["tables"]))
    # tables-only: the dense MLPs are frozen, updates are purely row deltas
    p_out = t1.params()
    assert p_out["bot_mlp"] is params["bot_mlp"]
    assert p_out["top_mlp"] is params["top_mlp"]

    def mk_src():
        return OnlineSource(mk(), interval_s=0.5, steps_per_update=2,
                            n_updates=3, salt_fn=lambda t: int(t * 10))

    src = mk_src()
    assert src.next_time() == 0.5
    got = src.poll(1.0)
    assert [b.version for b in got] == [1, 2]
    assert [b.t_emit_s for b in got] == [0.5, 1.0]
    assert src.next_time() == 1.5
    ch = src.run_to(5.0)                      # capped by n_updates
    assert len(ch) == 3 and src.next_time() is None
    # the schedule is a pure function of (trainer seed, interval, salts):
    # an identically-built source emits the SAME stream, bitwise
    ch2 = mk_src().run_to(5.0)
    for a, b in zip(ch.emitted, ch2.emitted):
        assert (a.version, a.t_emit_s, a.step) == (b.version, b.t_emit_s,
                                                   b.step)
        for da, db in zip(a.deltas, b.deltas):
            assert np.array_equal(da.rows, db.rows)
            assert np.array_equal(da.values, db.values)


# ---------------------------------------------------------------------------
# Coherence protocol (unit, per cache surface)
# ---------------------------------------------------------------------------
def test_coherence_remote_cache_modes():
    from repro.core import tiered_embedding as te
    from repro.fabric import RemoteRowCache
    from repro.online import DeltaBatch, RowDelta, apply_to_remote_cache
    from repro.online.coherence import check_mode

    with pytest.raises(ValueError, match="coherence mode"):
        check_mode("gossip")

    cfg = _cfg()
    remote = [0, 1, 2, 3]
    freq = te.measure_row_freq(cfg, alpha=1.2, seed=0, n_batches=8)
    d = cfg.embed_dim

    def touched_batch(cache):
        """Rows of remote table 0: some cached, some not — plus rows of a
        LOCAL table, which coherence must never touch."""
        cached0 = np.flatnonzero(cache._cached[0])[:4]
        uncached0 = np.setdiff1d(np.arange(cfg.rows_per_table),
                                 np.flatnonzero(cache._cached[0]))[:4]
        rows0 = np.unique(np.concatenate([cached0, uncached0]))
        return cached0, DeltaBatch(
            version=1, t_emit_s=0.1, step=1, deltas=(
                RowDelta(0, rows0, np.ones((len(rows0), d), np.float32)),
                RowDelta(5, np.arange(4),
                         np.ones((4, d), np.float32))))

    # -- invalidate: cached copies dropped, counts survive -------------------
    cache = RemoteRowCache(cfg, remote, capacity_rows=32)
    cache.warm(freq)
    cached0, batch = touched_batch(cache)
    assert len(cached0) > 0
    counts_before = cache._counts.copy()
    inv, adm = apply_to_remote_cache(cache, batch, now=0.1,
                                     mode="invalidate")
    assert inv == len(cached0) and adm == 0
    assert not cache._cached[0, cached0].any()
    assert np.array_equal(cache._counts, counts_before)

    # -- propagate: rows refreshed/admitted, never over capacity, never a
    # local row ---------------------------------------------------------------
    cache2 = RemoteRowCache(cfg, remote, capacity_rows=32)
    cache2.warm(freq)
    cached0, batch = touched_batch(cache2)
    rows0 = batch.deltas[0].rows
    inv, adm = apply_to_remote_cache(cache2, batch, now=0.1,
                                     mode="propagate")
    assert inv == 0 and adm == len(rows0)
    assert cache2._cached[0, rows0].all()
    assert not cache2._cached[5].any()          # local table: untouched
    assert cache2.cached_rows <= cache2.capacity_rows

    # -- propagate into a FULL tiny cache: LRU eviction keeps the bound ------
    tiny = RemoteRowCache(cfg, remote, capacity_rows=4)
    tiny.warm(freq)
    big_rows = np.arange(8)
    big = DeltaBatch(version=1, t_emit_s=0.2, step=1, deltas=(
        RowDelta(1, big_rows, np.ones((8, d), np.float32)),))
    apply_to_remote_cache(tiny, big, now=0.2, mode="propagate")
    assert tiny.cached_rows <= tiny.capacity_rows


def test_coherence_tiered_and_hoststore_write_through():
    import jax
    import jax.numpy as jnp

    from repro.core import tiered_embedding as te
    from repro.hoststore.chunks import ChunkParamMgr
    from repro.online import (DeltaBatch, RowDelta, refresh_tiered,
                              write_through_host)

    T, R, d, H = 3, 64, 8, 8
    tables = jax.random.normal(jax.random.PRNGKey(0), (T, R, d), jnp.float32)
    freq = np.zeros((T, R), np.int32)
    freq[0, :H] = np.arange(H, 0, -1)          # table 0 rows 0..H-1 are hot
    tiered = te.build_tiered_tables(tables, jnp.asarray(freq), H)
    rows = np.array([2, 5, 40])                # 2 hot + 1 bulk-only
    vals = np.arange(len(rows) * d, dtype=np.float32).reshape(len(rows), d)
    batch = DeltaBatch(version=1, t_emit_s=0.0, step=1, deltas=(
        RowDelta(0, rows, vals),))

    fresh, n_fast = refresh_tiered(tiered, batch)
    assert n_fast == 2                          # rows 2 and 5 have fast slots
    assert np.array_equal(np.asarray(fresh.bulk)[0, rows], vals)
    slots = np.asarray(fresh.row_map)[0, rows[:2]]
    assert (slots >= 0).all()
    assert np.array_equal(np.asarray(fresh.fast)[0, slots], vals[:2])
    # bulk row with no fast slot: only the bulk copy moved
    assert int(np.asarray(fresh.row_map)[0, 40]) < 0

    # -- hoststore: host canonical takes all rows; resident device chunks
    # are refreshed in place --------------------------------------------------
    mgr = ChunkParamMgr(tables, chunk_rows=8, cache_slots=4)
    mgr.ensure(np.array([0, 0]), np.array([2, 5]))      # chunk 0 resident
    n_dev = write_through_host(mgr, batch)
    assert n_dev == 2                           # rows 2,5 resident; 40 not
    assert np.array_equal(mgr.host[0, rows], vals)
    pos = mgr.host_pos[0, rows[:2]]
    assert (pos < mgr.pad_pos).all()
    assert np.array_equal(np.asarray(mgr.device_cache)[pos], vals[:2])
    assert mgr.host_pos[0, 40] == mgr.pad_pos   # still not resident


# ---------------------------------------------------------------------------
# Fleet: update barriers, accounting, served-version correctness
# ---------------------------------------------------------------------------
def test_fleet_applies_updates_and_accounts():
    from repro.fabric import ShardedFleet
    from repro.online import DeltaChannel, OnlineReport

    cfg = _cfg()
    events = make_scenario("zipf_drift", alpha=1.2, rotate_every_s=0.02,
                           salt_stride=37).events(10, qps=2000.0, seed=3)
    horizon = events[-1].arrival_s
    batches = [_rand_batch(cfg, 11, 1, 0.3 * horizon),
               _rand_batch(cfg, 12, 2, 0.6 * horizon)]
    n_rows = sum(b.n_rows for b in batches)

    for mode in ("invalidate", "propagate"):
        fleet = ShardedFleet(cfg, n_boards=2, alpha=1.05, seed=0,
                             max_batch_queries=2)
        base = fleet._tables_host.copy()
        r = fleet.run(events, online=DeltaChannel(batches), coherence=mode)
        assert isinstance(r.online, OnlineReport)
        assert r.online.mode == mode
        assert r.online.n_updates == 2 and r.online.last_version == 2
        assert r.online.rows_pushed == n_rows
        assert r.online.staleness_max_s >= 0.0
        # the host canonical ends at exactly the last version
        assert np.array_equal(fleet._tables_host, _apply(base, batches))
        # metrics registry carries the same ledger
        m = fleet.metrics
        assert m.value("update_batches") == 2
        assert m.total("rows_pushed") == n_rows
        assert m.histogram("update_staleness_s").count == 2
        assert m.value("cache_invalidated_rows", cause="update") \
            == r.online.cache_invalidated_rows
        assert m.value("rows_propagated") == r.online.rows_propagated
        if mode == "invalidate":
            assert r.online.rows_propagated == 0
        # attribution still closes with the update_stall component
        assert _closure_residual(fleet.attribution.records) < 1e-9

    # no channel -> no online ledger
    frozen = ShardedFleet(cfg, n_boards=2, alpha=1.05, seed=0,
                          max_batch_queries=2)
    assert frozen.run(events).online is None


def test_served_version_matches_owner_latest():
    """Every query's served values are the owner's LATEST VISIBLE version:
    bit-equal to a frozen single-board fleet holding exactly the tables
    with V(q) = #{batches emitted at or before its arrival} applied."""
    import jax

    from repro.core.dlrm import init_dlrm
    from repro.fabric import ShardedFleet
    from repro.online import DeltaChannel

    cfg = _cfg()
    events = make_scenario("zipf_drift", alpha=1.2, rotate_every_s=0.02,
                           salt_stride=37).events(8, qps=2000.0, seed=3)
    arr = [e.arrival_s for e in events]
    # emit strictly BETWEEN arrivals, so visibility is unambiguous
    batches = [_rand_batch(cfg, 21, 1, (arr[2] + arr[3]) / 2),
               _rand_batch(cfg, 22, 2, (arr[5] + arr[6]) / 2)]

    fleet = ShardedFleet(cfg, n_boards=2, alpha=1.05, seed=0,
                         max_batch_queries=1)
    params0 = init_dlrm(jax.random.PRNGKey(0), cfg)
    base = np.array(params0["tables"])
    assert np.array_equal(fleet._tables_host, base)
    fleet.run(events, online=DeltaChannel(batches), coherence="propagate")

    visible = {ev.qid: sum(b.t_emit_s <= ev.arrival_s for b in batches)
               for ev in events}
    assert set(visible.values()) == {0, 1, 2}   # all three versions served
    for v in sorted(set(visible.values())):
        ref = ShardedFleet(cfg, n_boards=1, alpha=1.05, seed=0,
                           max_batch_queries=1,
                           params={**params0,
                                   "tables": _apply(base, batches[:v])})
        ref.run(events)
        for ev in events:
            if visible[ev.qid] != v:
                continue
            assert np.array_equal(fleet.completed[ev.qid].probs,
                                  ref.completed[ev.qid].probs), \
                f"query {ev.qid} diverged from its version-{v} reference"


def test_online_random_interleaving_bit_identity_property():
    """THE online invariant, property-tested: random row pushes + lookups
    interleaved across a 2-board fabric serve bit-identically to the
    1-board online reference at every interleaving point, the host
    canonical converges to the last version, and the latency attribution
    closes exactly with update_stall. Uses Hypothesis when available;
    otherwise falls back to a seeded random case sweep."""
    from repro.fabric import ShardedFleet
    from repro.online import DeltaChannel

    cfg = _cfg()
    events = make_scenario("zipf_drift", alpha=1.2, rotate_every_s=0.02,
                           salt_stride=37).events(10, qps=2000.0, seed=3)
    horizon = events[-1].arrival_s

    def check(fracs, seeds, mode):
        batches = [_rand_batch(cfg, seeds[i], i + 1, fracs[i] * horizon)
                   for i in range(len(fracs))]

        def serve(k):
            fleet = ShardedFleet(cfg, n_boards=k, alpha=1.05, seed=0,
                                 max_batch_queries=2,
                                 router="jsq" if k > 1 else "round_robin")
            base = fleet._tables_host.copy()
            fleet.run(events, online=DeltaChannel(batches), coherence=mode)
            return fleet, base

        (ref, base), (fleet, _) = serve(1), serve(2)
        for ev in events:
            assert np.array_equal(ref.completed[ev.qid].probs,
                                  fleet.completed[ev.qid].probs), \
                f"query {ev.qid} diverged between 1 and 2 boards"
        # both fleets converge to exactly the last visible version
        expected = _apply(base, batches)
        assert np.array_equal(ref._tables_host, expected)
        assert np.array_equal(fleet._tables_host, expected)
        assert fleet.metrics.histogram("update_staleness_s").count \
            == len(batches)
        for f in (ref, fleet):
            assert _closure_residual(f.attribution.records) < 1e-9

    try:
        from hypothesis import HealthCheck, given, settings
        from hypothesis import strategies as st
    except ImportError:
        rng = np.random.default_rng(0)
        for i, mode in enumerate(("invalidate", "propagate", "propagate")):
            n_b = 1 + i
            check(sorted(rng.uniform(0.02, 0.98, n_b).tolist()),
                  rng.integers(0, 2 ** 16, n_b).tolist(), mode)
        return

    @settings(max_examples=4, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(data=st.data())
    def run(data):
        n_b = data.draw(st.integers(1, 3))
        fracs = sorted(data.draw(st.lists(
            st.floats(0.02, 0.98, allow_nan=False), min_size=n_b,
            max_size=n_b)))
        seeds = data.draw(st.lists(st.integers(0, 2 ** 16), min_size=n_b,
                                   max_size=n_b))
        mode = data.draw(st.sampled_from(("invalidate", "propagate")))
        check(fracs, seeds, mode)

    run()


# ---------------------------------------------------------------------------
# Cluster broadcast
# ---------------------------------------------------------------------------
def test_cluster_broadcasts_updates_bit_identically():
    from repro.cluster import Cluster
    from repro.obs.serialize import to_jsonable
    from repro.online import DeltaBatch, DeltaChannel, OnlineReport, RowDelta

    cfg = _cfg()
    events = make_scenario("stationary", alpha=1.05).events(8, qps=2000.0,
                                                            seed=2)
    arr = [e.arrival_s for e in events]
    rng = np.random.default_rng(7)
    # a full-table rewrite guarantees every post-update lookup moves
    full = DeltaBatch(version=1, t_emit_s=(arr[0] + arr[1]) / 2, step=1,
                      deltas=tuple(
                          RowDelta(t, np.arange(cfg.rows_per_table),
                                   rng.standard_normal(
                                       (cfg.rows_per_table, cfg.embed_dim))
                                   .astype(np.float32))
                          for t in range(cfg.num_tables)))
    # max_batch_queries=1 pins the batch composition: with one query per
    # micro-batch the served values are routing- and barrier-independent,
    # so replica count must be bit-invisible (the replica path is
    # composition-SENSITIVE in the last float bit, like any XLA batching)
    kw = dict(alpha=1.05, seed=0, max_batch_queries=1)

    c1 = Cluster(cfg, n_replicas=1, **kw)
    c1.run(events, online=DeltaChannel([full]))
    c2 = Cluster(cfg, n_replicas=2, **kw)
    r2 = c2.run(events, online=DeltaChannel([full]))
    frozen = Cluster(cfg, n_replicas=2, **kw)
    frozen.run(events)

    # broadcast keeps replica count out of the served values
    for ev in events:
        assert np.array_equal(c1.completed[ev.qid].probs,
                              c2.completed[ev.qid].probs)
    # the update genuinely changed what is served...
    assert any(not np.array_equal(frozen.completed[ev.qid].probs,
                                  c2.completed[ev.qid].probs)
               for ev in events[1:])
    # ...but queries that arrived BEFORE the emit flushed pre-update
    assert np.array_equal(frozen.completed[events[0].qid].probs,
                          c2.completed[events[0].qid].probs)
    assert isinstance(r2.online, OnlineReport)
    assert r2.online.n_updates == 1
    assert r2.online.rows_pushed == cfg.num_tables * cfg.rows_per_table
    doc = to_jsonable(r2.online)
    assert doc["kind"] == "OnlineReport"
    assert c2.metrics.histogram("update_staleness_s").count == 1


# ---------------------------------------------------------------------------
# Metrics scoping (regression: cross-run contamination)
# ---------------------------------------------------------------------------
def test_metrics_scoped_per_run_no_cross_contamination():
    """Two serving runs handed their OWN registries must each count
    exactly their own queries, and must leave the process-wide singleton
    untouched; runs without `metrics=` still land on the singleton."""
    from repro.engine import Engine
    from repro.obs.metrics import MetricsRegistry, default_registry

    cfg = _cfg()
    sess = Engine(cfg, plan="none", alpha=1.05).serve_session(
        max_batch_queries=2)
    before = default_registry().total("queries_served")
    m1, m2 = MetricsRegistry(), MetricsRegistry()
    sess.run_open_loop(6, 2000.0, metrics=m1)
    sess.run_open_loop(6, 2000.0, metrics=m2)
    assert m1.total("queries_served") == 6
    assert m2.total("queries_served") == 6
    assert default_registry().total("queries_served") == before
    # the singleton is still the default sink
    sess.run_serial(3)
    assert default_registry().total("queries_served") == before + 3


# ---------------------------------------------------------------------------
# Bench registration
# ---------------------------------------------------------------------------
def test_bench_online_registered():
    if REPO not in sys.path:
        sys.path.insert(0, REPO)
    from benchmarks import run as bench_run

    names = {name for name, _ in bench_run.SECTIONS}
    assert "online" in names
    for section in ("online", "pipeline", "tiered_embedding",
                    "engine_serve"):
        assert section in bench_run.EMITS_JSON
