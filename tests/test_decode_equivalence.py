"""decode == prefill == full-forward equivalence, per architecture family.

This is the serving-correctness contract: the cached single-token path and
the parallel (blockwise/collect) prefill must agree with the plain forward
bit-for-bit in bf16 (identical op order per layer).

MoE note: capacity-based token dropping depends on the TOTAL token count
(N = B·T), so a full-sequence forward may drop tokens that single-token
decode would not — that is inherent to capacity MoE, not a bug. Equivalence
tests therefore raise capacity_factor so nothing drops; drop behaviour is
covered separately in test_moe_capacity_drops."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS
from repro.models import lm, transformer as T


def _no_drop(cfg):
    """Raise MoE capacity so forward and decode route identically."""
    if cfg.moe is None:
        return cfg
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))

FAMILIES = ["internlm2-1.8b",       # dense GQA
            "h2o-danube-3-4b",      # SWA ring cache
            "deepseek-7b",          # MHA (kv == heads)
            "rwkv6-3b",             # rwkv state
            "jamba-1.5-large-398b", # mamba + attn hybrid
            "mixtral-8x7b",         # moe + swa
            "whisper-base",         # enc-dec + cross attention
            "internvl2-26b"]        # vlm frontend


@pytest.mark.parametrize("arch", FAMILIES)
def test_token_by_token_decode_matches_forward(arch):
    cfg = _no_drop(ARCHS[arch].reduced())
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    batch = lm.smoke_batch(cfg, 2, 10)
    toks = batch["tokens"]

    memory_kv = None
    if cfg.is_encoder_decoder:
        enc_out = T.encode(params, cfg, batch["encoder_embeds"])
        memory_kv = T._project_kv_memory(cfg, params["cross_attn"], enc_out)
        h_full = T.forward(params, cfg, toks,
                           encoder_embeds=batch["encoder_embeds"])
    elif cfg.frontend is not None:
        pytest.skip("frontend tokens change positions; covered by prefill test")
    else:
        h_full = T.forward(params, cfg, toks)

    caches = T.init_cache(cfg, 2, 16)
    hs = []
    for t in range(toks.shape[1]):
        hid, caches = T.forward_with_state(
            params, cfg, toks[:, t:t + 1], caches, jnp.asarray(t),
            memory_kv=memory_kv)
        hs.append(hid[:, 0])
    h_dec = jnp.stack(hs, axis=1)
    np.testing.assert_allclose(np.asarray(h_full, np.float32),
                               np.asarray(h_dec, np.float32),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "h2o-danube-3-4b",
                                  "rwkv6-3b", "jamba-1.5-large-398b",
                                  "mixtral-8x7b"])
def test_parallel_prefill_then_decode_greedy(arch):
    """Greedy continuation from the parallel prefill must equal greedy from
    the full forward at every generated position."""
    cfg = _no_drop(ARCHS[arch].reduced())
    params = T.init_model(jax.random.PRNGKey(1), cfg)
    batch = lm.smoke_batch(cfg, 2, 12)
    toks = batch["tokens"]

    prefill = lm.make_prefill_step(cfg, max_len=24)
    decode = lm.make_decode_step(cfg)
    caches, cur = prefill(params, {"tokens": toks})
    seq = toks
    for i in range(4):
        # reference next token from full forward
        h = T.forward(params, cfg, seq)
        ref_logits = T.logits_from_hidden(params, cfg, h[:, -1:, :])
        ref_next = jnp.argmax(ref_logits[:, 0, :cfg.vocab_size], axis=-1)
        assert bool((cur == ref_next).all()), f"step {i}"
        seq = jnp.concatenate([seq, cur[:, None]], axis=1)
        caches, cur = decode(params, caches, cur, jnp.asarray(12 + i))


def test_sliding_window_ring_cache_eviction():
    """The SWA ring cache holds exactly the last `window` positions, and the
    decode mask ignores any stale slot."""
    from repro.models.layers import decode_attention

    cfg = ARCHS["h2o-danube-3-4b"].reduced()    # window 16 after reduce()
    assert cfg.sliding_window == 16
    params = T.init_model(jax.random.PRNGKey(2), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 20), 0, cfg.vocab_size)
    caches = T.init_cache(cfg, 1, 32)
    for t in range(20):
        _, caches = T.forward_with_state(params, cfg, toks[:, t:t + 1],
                                         caches, jnp.asarray(t))
    pos = np.asarray(caches[0]["pos"])          # (U, B, S=16)
    assert pos.shape[-1] == 16                  # ring sized to the window
    assert set(pos.reshape(-1).tolist()) == set(range(4, 20))

    # masking: a stale slot (pos outside the window) must not affect output
    k = jax.random.PRNGKey(4)
    q = jax.random.normal(k, (1, 1, 4, 8))
    kc = jax.random.normal(jax.random.PRNGKey(5), (1, 16, 2, 8))
    vc = jax.random.normal(jax.random.PRNGKey(6), (1, 16, 2, 8))
    kpos = jnp.arange(4, 20)[None, :]           # slot 0 holds pos 4 ... etc
    out1 = decode_attention(q, kc, vc, jnp.asarray(19), kpos, window=16)
    stale = kpos.at[0, 0].set(3)                # now outside window of pos 19
    kc2 = kc.at[:, 0].set(1e3)                  # poison the stale slot
    out2 = decode_attention(q, kc2, vc, jnp.asarray(19), stale, window=16)
    out1b = decode_attention(q, kc, vc, jnp.asarray(19), stale, window=16)
    np.testing.assert_allclose(np.asarray(out2, np.float32),
                               np.asarray(out1b, np.float32), rtol=1e-5)
