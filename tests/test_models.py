"""Per-architecture smoke tests: every assigned arch instantiates a REDUCED
config, runs one forward + one train step on CPU, asserts shapes + no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ARCHS
from repro.data import make_lm_batch
from repro.models import lm, transformer as T
from repro.optim import adamw

ALL_ARCHS = sorted(ARCHS)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg = ARCHS[arch].reduced()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    batch = lm.smoke_batch(cfg, batch=2, seq=16)
    hidden = T.forward(params, cfg, batch["tokens"],
                       frontend_embeds=batch.get("frontend_embeds"),
                       encoder_embeds=batch.get("encoder_embeds"))
    fe = cfg.n_frontend_tokens if (cfg.frontend and not cfg.is_encoder_decoder) else 0
    assert hidden.shape == (2, 16 + fe, cfg.d_model)
    assert not bool(jnp.isnan(hidden.astype(jnp.float32)).any())
    logits = T.logits_from_hidden(params, cfg, hidden)
    assert logits.shape[-1] == cfg.padded_vocab


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_one_train_step(arch):
    cfg = ARCHS[arch].reduced()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    opt = adamw(1e-3)
    step = jax.jit(lm.make_train_step(cfg, opt))
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    batch = make_lm_batch(cfg, 0, batch=2, seq=17)
    state, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert loss == loss, "loss is NaN"          # NaN check
    assert 0.0 < loss < 20.0
    assert int(state["step"]) == 1


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "rwkv6-3b",
                                  "mixtral-8x7b", "jamba-1.5-large-398b"])
def test_loss_decreases(arch):
    cfg = ARCHS[arch].reduced()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    opt = adamw(3e-3)
    step = jax.jit(lm.make_train_step(cfg, opt))
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    losses = []
    for s in range(12):
        batch = make_lm_batch(cfg, s, batch=4, seq=33)
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert min(losses[-3:]) < losses[0], losses


def test_param_counts_in_expected_range():
    """Full-config param counts must be in the ballpark of the arch names."""
    expectations = {
        "command-r-plus-104b": (90e9, 130e9),
        "deepseek-7b": (5e9, 9e9),
        "internlm2-1.8b": (1.2e9, 2.5e9),
        "mixtral-8x7b": (40e9, 55e9),
        "llama4-maverick-400b-a17b": (330e9, 480e9),
        "jamba-1.5-large-398b": (300e9, 480e9),
        "rwkv6-3b": (2e9, 4.5e9),
        "whisper-base": (0.04e9, 0.2e9),
    }
    for arch, (lo, hi) in expectations.items():
        n = ARCHS[arch].param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.1f}B not in [{lo/1e9},{hi/1e9}]"


def test_moe_active_params_smaller():
    for arch in ("mixtral-8x7b", "llama4-maverick-400b-a17b",
                 "jamba-1.5-large-398b"):
        cfg = ARCHS[arch]
        assert cfg.param_count(active_only=True) < 0.55 * cfg.param_count()


def test_sub_quadratic_flags():
    """long_500k applicability matches DESIGN.md §3."""
    expect_subq = {"rwkv6-3b", "jamba-1.5-large-398b", "h2o-danube-3-4b",
                   "mixtral-8x7b"}
    for name, cfg in ARCHS.items():
        assert cfg.sub_quadratic == (name in expect_subq), name
