"""Fused serve megakernel: equivalence vs the composed path, at every layer.

Layer 1: the Pallas kernels (interpret mode) vs the composed jnp oracle —
shape sweeps that hit the batch-pad path, T=1, block_b > B clamping, and
the tiered/grouped layouts at hot fractions {0, 0.1, 1}.
Layer 2: no-leak — poisoned pad-gather rows must never reach real outputs.
Layer 3: the serve session — fused vs composed sessions are bit-identical
on CPU (the fused ops dispatch to the same composed jnp graph off-TPU),
the kernel choice is recorded, and non-local exchanges fall back.
Layer 4: the measured-kernel-times calibration the bench artifact feeds
into `perf_model.inference_breakdown`.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_dlrm
from repro.kernels import ref
from repro.kernels.fused_serve import (fused_bag_interactions_pallas,
                                       fused_cached_bag_interactions_pallas,
                                       fused_grouped_bag_interactions_pallas)


def _inputs(key, B, T, L, R, d):
    k1, k2, k3 = jax.random.split(key, 3)
    tables = jax.random.normal(k1, (T, R, d), jnp.float32)
    idx = jax.random.randint(k2, (B, T, L), 0, R)
    bot = jax.random.normal(k3, (B, d), jnp.float32)
    return tables, idx, bot


# ------------------------------------------------------- single-tier kernel
@pytest.mark.parametrize("B,T,L,R,d,bb", [
    (6, 3, 4, 16, 8, 4),     # B not a multiple of block_b: pad path
    (4, 1, 5, 32, 16, 4),    # single table
    (3, 2, 2, 8, 8, 64),     # block_b > B: clamps to B
    (8, 5, 3, 24, 16, 4),    # exact blocking
])
def test_fused_matches_composed(B, T, L, R, d, bb):
    tables, idx, bot = _inputs(jax.random.PRNGKey(B * 10 + T), B, T, L, R, d)
    got = fused_bag_interactions_pallas(tables, idx, bot, block_b=bb,
                                        interpret=True)
    want = ref.interactions_ref(bot, ref.embedding_bag_ref(tables, idx))
    assert got.shape == (B, d + (T + 1) * T // 2)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# -------------------------------------------------- two-tier (cached) kernel
def _pack_two_tier(tables, idx, hot_fraction, key):
    """cached_embedding_bag layout: hot rows packed into a fast tier with
    zeros miss slot S; bulk keeps every row plus a zeros hit slot R."""
    T, R, d = tables.shape
    hot = np.asarray(jax.random.bernoulli(key, hot_fraction, (T, R)))
    tabs = np.asarray(tables)
    S = max(int(hot.sum(axis=1).max()), 1)
    fast = np.zeros((T, S + 1, d), np.float32)
    slot = np.full((T, R), S, np.int32)
    for t in range(T):
        rows = np.flatnonzero(hot[t])
        fast[t, :len(rows)] = tabs[t, rows]
        slot[t, rows] = np.arange(len(rows))
    bulk = np.concatenate([tabs, np.zeros((T, 1, d), np.float32)], axis=1)
    idx_np = np.asarray(idx)
    t_ax = np.arange(T)[None, :, None]
    fi = jnp.asarray(slot[t_ax, idx_np])
    bi = jnp.asarray(np.where(hot[t_ax, idx_np], R, idx_np))
    return jnp.asarray(fast), jnp.asarray(bulk), fi, bi


@pytest.mark.parametrize("hot_fraction", [0.0, 0.1, 1.0])
def test_fused_cached_matches_composed(hot_fraction):
    B, T, L, R, d = 5, 3, 4, 16, 8
    tables, idx, bot = _inputs(jax.random.PRNGKey(17), B, T, L, R, d)
    fast, bulk, fi, bi = _pack_two_tier(tables, idx, hot_fraction,
                                        jax.random.PRNGKey(18))
    got = fused_cached_bag_interactions_pallas(fast, bulk, fi, bi, bot,
                                               block_b=4, interpret=True)
    want = ref.fused_bag_interactions_ref(tables, idx, bot)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    # and against the cached-layout composed oracle, same translated streams
    want2 = ref.interactions_ref(
        bot, ref.cached_embedding_bag_ref(fast, bulk, fi, bi))
    np.testing.assert_allclose(got, want2, rtol=1e-5, atol=1e-5)


# ------------------------------------------- grouped (tiered-plan) kernel
def _grouped_case(fast_ids, bulk_ids, B=5, L=3, R=16, d=8, seed=23):
    from repro.parallel.plan import PlanGroups

    T = len(fast_ids) + len(bulk_ids)
    tables, idx, bot = _inputs(jax.random.PRNGKey(seed), B, T, L, R, d)
    groups = PlanGroups(tuple(fast_ids), tuple(bulk_ids))
    perm = np.asarray(groups.fast_ids + groups.bulk_ids, np.int32)
    tf = tables[jnp.asarray(groups.fast_ids, jnp.int32)] if fast_ids \
        else tables[:0]
    tb = tables[jnp.asarray(groups.bulk_ids, jnp.int32)] if bulk_ids \
        else tables[:0]
    got = fused_grouped_bag_interactions_pallas(
        tf, tb, idx[:, perm, :], bot, inv_perm=groups.inv_perm,
        block_b=4, interpret=True)
    want = ref.fused_bag_interactions_ref(tables, idx, bot)
    return got, want


def test_fused_grouped_matches_original_order():
    # non-trivial interleaved permutation: fast {2, 0}, bulk {4, 1, 3}
    got, want = _grouped_case([2, 0], [4, 1, 3])
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("fast_ids,bulk_ids", [
    ([3, 1, 0, 2], []),      # empty bulk: delegates to single-tier
    ([], [1, 3, 0, 2]),      # empty fast
])
def test_fused_grouped_empty_group_delegates(fast_ids, bulk_ids):
    got, want = _grouped_case(fast_ids, bulk_ids)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_fused_grouped_matches_grouped_ref():
    from repro.parallel.plan import PlanGroups

    groups = PlanGroups((1, 2), (0, 3))
    B, L, R, d = 6, 3, 12, 8
    tables, idx, bot = _inputs(jax.random.PRNGKey(5), B, 4, L, R, d)
    perm = np.asarray(groups.fast_ids + groups.bulk_ids, np.int32)
    tf = tables[jnp.asarray(groups.fast_ids, jnp.int32)]
    tb = tables[jnp.asarray(groups.bulk_ids, jnp.int32)]
    idx_perm = idx[:, perm, :]
    got = fused_grouped_bag_interactions_pallas(
        tf, tb, idx_perm, bot, inv_perm=groups.inv_perm, block_b=4,
        interpret=True)
    want = ref.fused_grouped_bag_interactions_ref(
        tf, tb, idx_perm, bot, groups.inv_perm)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# -------------------------------------------------------------- no leakage
def test_pad_samples_never_leak_poisoned_row0():
    """_pad_batch pads with index 0: pad SAMPLES gather real row 0. Poison
    row 0 — real outputs must be untouched and finite even though every
    pad sample pools B*T*L copies of the poison."""
    B, T, L, R, d, bb = 5, 3, 4, 16, 8, 4            # pads 5 -> 8
    tables, idx, bot = _inputs(jax.random.PRNGKey(31), B, T, L, R, d)
    idx = jnp.clip(idx, 1, R - 1)                     # real samples avoid row 0
    poisoned = tables.at[:, 0, :].set(1e30)
    got = fused_bag_interactions_pallas(poisoned, idx, bot, block_b=bb,
                                        interpret=True)
    want = ref.fused_bag_interactions_ref(poisoned, idx, bot)
    assert np.isfinite(np.asarray(got)).all()
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_cached_miss_slots_never_leak():
    """The zeros miss slot S / hit slot R are load-bearing: every step DMAs
    one row from EACH tier, so the non-owning tier's row must contribute
    exactly 0. Poison every non-slot row that the translated streams never
    reference and check nothing bleeds through."""
    B, T, L, R, d = 4, 2, 3, 8, 8
    tables, idx, bot = _inputs(jax.random.PRNGKey(41), B, T, L, R, d)
    fast, bulk, fi, bi = _pack_two_tier(tables, idx, 0.5,
                                        jax.random.PRNGKey(42))
    want = ref.fused_bag_interactions_ref(tables, idx, bot)
    # pad samples (4 -> none at bb=4, force pad with bb=3) index slot 0 of
    # both tiers; poisoning any row OUTSIDE the zero slots must not matter
    got = fused_cached_bag_interactions_pallas(fast, bulk, fi, bi, bot,
                                               block_b=3, interpret=True)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    assert np.isfinite(np.asarray(got)).all()


# --------------------------------------------------------- ops dispatch
def test_ops_fused_dispatch_bitidentical_to_ref_on_cpu():
    """Off-TPU the ops wrappers run the composed reference graph, so they
    must be BIT-identical to ref — the property the serve-session
    equivalence tests below lean on."""
    from repro.kernels import ops

    B, T, L, R, d = 4, 3, 5, 16, 8
    tables, idx, bot = _inputs(jax.random.PRNGKey(51), B, T, L, R, d)
    got = ops.fused_bag_interactions(tables, idx, bot)
    want = ref.fused_bag_interactions_ref(tables, idx, bot)
    assert np.array_equal(np.asarray(got), np.asarray(want))

    from repro.parallel.plan import PlanGroups
    groups = PlanGroups((2, 0), (1,))
    perm = np.asarray(groups.fast_ids + groups.bulk_ids, np.int32)
    tf = tables[jnp.asarray(groups.fast_ids, jnp.int32)]
    tb = tables[jnp.asarray(groups.bulk_ids, jnp.int32)]
    got = ops.fused_grouped_bag_interactions(
        tf, tb, idx[:, perm, :], bot, inv_perm=groups.inv_perm)
    want = ref.fused_grouped_bag_interactions_ref(
        tf, tb, idx[:, perm, :], bot, groups.inv_perm)
    assert np.array_equal(np.asarray(got), np.asarray(want))


# ------------------------------------------------------- serve-path wiring
def _cfg():
    return dataclasses.replace(
        get_dlrm("dlrm-rm2-small-unsharded").reduced(), batch_size=8)


def _query(cfg, step, alpha=1.05):
    from repro.data import make_recsys_batch
    b = make_recsys_batch(cfg, step, 0, alpha)
    return b["dense"], b["indices"]


@pytest.mark.parametrize("plan", ["none", "auto"])
def test_serve_session_fused_matches_composed(plan):
    from repro.engine import Engine

    cfg = _cfg()
    s_fused = Engine(cfg, plan=plan, alpha=1.05).serve_session(
        max_batch_queries=4, max_wait_ms=1e6)
    s_comp = Engine(cfg, plan=plan, alpha=1.05,
                    fused_serve="off").serve_session(
        max_batch_queries=4, max_wait_ms=1e6)
    assert s_fused.serve_kernel == "fused"
    assert s_comp.serve_kernel == "composed"
    for step in range(2):
        dense, idx = _query(cfg, step)
        a = s_fused.serve_direct(dense, idx)
        b = s_comp.serve_direct(dense, idx)
        # identical jnp graphs on CPU -> bitwise equal, not just allclose
        assert np.array_equal(a, b)
        assert np.isfinite(a).all() and a.shape == (cfg.batch_size,)


def test_serve_kernel_recorded_on_plan_report():
    from repro.engine import Engine

    eng = Engine(_cfg(), plan="auto", alpha=1.05)
    sess = eng.serve_session(max_batch_queries=4, max_wait_ms=1e6)
    rep = eng.plan_report("inference")
    assert rep is not None
    assert rep.serve_kernel == sess.serve_kernel == "fused"
    assert "serve_kernel=fused" in rep.summary()

    eng_off = Engine(_cfg(), plan="auto", alpha=1.05, fused_serve="off")
    sess_off = eng_off.serve_session(max_batch_queries=4, max_wait_ms=1e6)
    assert sess_off.serve_kernel == "composed"
    assert eng_off.plan_report("inference").serve_kernel == "composed"


def test_row_wise_exchange_falls_back_to_composed():
    """Distributed-style exchanges have no local fused path: the session
    must transparently serve composed — and still match bitwise."""
    from repro import parallel
    from repro.engine import Engine

    cfg = dataclasses.replace(_cfg(), sharding="row_wise")
    ex = parallel.make_exchange(cfg, "model", 1)
    assert not ex.supports_fused_forward()
    with pytest.raises(NotImplementedError):
        ex.fused_forward({}, None, None)

    sess = Engine(cfg, plan="none").serve_session(
        max_batch_queries=4, max_wait_ms=1e6)
    assert sess.serve_kernel == "composed"        # fused requested, denied
    sess_off = Engine(cfg, plan="none", fused_serve="off").serve_session(
        max_batch_queries=4, max_wait_ms=1e6)
    dense, idx = _query(cfg, 0)
    assert np.array_equal(sess.serve_direct(dense, idx),
                          sess_off.serve_direct(dense, idx))


def test_engine_rejects_bad_fused_serve():
    from repro.engine import Engine

    with pytest.raises(ValueError, match="fused_serve"):
        Engine(_cfg(), plan="none", fused_serve="on")


# ----------------------------------------------- kernel_times calibration
def test_kernel_times_from_accepts_both_entry_forms():
    from repro.core.calibration import kernel_times_from

    kt = kernel_times_from({"kernel_times": {
        "fused_bag_interactions": {"us": 412.0, "shape": "B200 T40"},
        "embedding_bag": 389.5,
        "interactions": 55}})
    assert kt == {"fused_bag_interactions": 412.0,
                  "embedding_bag": 389.5, "interactions": 55.0}
    assert all(isinstance(v, float) for v in kt.values())


@pytest.mark.parametrize("bad", [
    {},                                             # no kernel_times at all
    {"kernel_times": {}},                           # empty section
    {"kernel_times": []},                           # wrong container
    {"kernel_times": {"k": "fast"}},                # non-numeric
    {"kernel_times": {"k": True}},                  # bool is not a time
    {"kernel_times": {"k": -3.0}},                  # negative
    {"kernel_times": {"k": float("nan")}},          # non-finite
    {"kernel_times": {"k": {"us": 1.0, "shape": 3}}},   # non-string label
    {"kernel_times": {"k": {"shape": "B1"}}},       # dict without us
])
def test_kernel_times_from_rejects_malformed(bad):
    from repro.core.calibration import kernel_times_from

    with pytest.raises(ValueError):
        kernel_times_from(bad)


def test_inference_breakdown_consumes_measured_kernel_times():
    from repro.core import perf_model

    cfg = get_dlrm("dlrm-rm2-small-unsharded")
    sys_ = perf_model.recspeed_hybrid_system()
    plain = perf_model.inference_breakdown(cfg, sys_)
    cal = {"kernel_times": {
        "fused_bag_interactions": {"us": 412.0, "shape": "B200"},
        "embedding_bag": 900.0, "interactions": 55.0}}
    bd = perf_model.inference_breakdown(cfg, sys_, calibration=cal)
    # the fused entry wins the lookup override (priority over embedding_bag)
    assert bd.t_lookup == pytest.approx(412e-6)
    assert bd.notes["t_lookup_modeled_s"] == pytest.approx(plain.t_lookup)
    assert bd.notes["t_lookup_delta_s"] == pytest.approx(
        412e-6 - plain.t_lookup)
    assert bd.notes["kernel_us_fused_bag_interactions"] == 412.0
    # interactions is delta-reported, never an override (t_dense_fwd also
    # carries the MLP flops)
    assert bd.t_dense_fwd == pytest.approx(plain.t_dense_fwd)
    assert bd.notes["interactions_delta_vs_dense_fwd_s"] == pytest.approx(
        55e-6 - plain.t_dense_fwd)
    # t_fwd recomputed from the measured term
    assert bd.t_fwd == pytest.approx(
        bd.t_idx_a2a + max(bd.t_lookup, bd.t_emb_exchange, bd.t_dense_fwd))

    # without the fused entry the next bag-family kernel takes the override
    bd2 = perf_model.inference_breakdown(
        cfg, sys_, calibration={"kernel_times": {"embedding_bag": 900.0}})
    assert bd2.t_lookup == pytest.approx(900e-6)
    # a kernel_times section with no bag-family entry changes nothing
    bd3 = perf_model.inference_breakdown(
        cfg, sys_, calibration={"kernel_times": {"interactions": 55.0}})
    assert bd3.t_lookup == pytest.approx(plain.t_lookup)
    assert "t_lookup_modeled_s" not in bd3.notes
