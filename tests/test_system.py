"""End-to-end behaviour tests: the full train loop with checkpoint-resume,
the serve CLI's SLA accounting, planner placement, and elastic re-mesh."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_dlrm
from repro.launch.mesh import make_host_mesh


def test_train_loop_with_resume(tmp_path):
    """Train 6 steps with ckpt_every=3, kill, resume, and verify the resumed
    run continues from step 3 with identical data (step-indexed pipeline)."""
    from repro.checkpoint import CheckpointManager
    from repro.core import dlrm as dlrm_lib
    from repro.data import make_recsys_batch
    from repro.runtime import TrainLoop

    cfg = get_dlrm("dlrm-rm2-small-unsharded").reduced()

    def make_loop(ckpt_dir):
        def step_fn(state, batch):
            params, loss = dlrm_lib.reference_train_step(
                state, batch["dense"], batch["indices"], batch["labels"],
                cfg, 0.05)
            return params, {"loss": loss}
        return TrainLoop(step_fn=step_fn,
                         batch_fn=lambda s: make_recsys_batch(cfg, s),
                         ckpt=CheckpointManager(str(ckpt_dir)), ckpt_every=3)

    params0 = dlrm_lib.init_dlrm(jax.random.PRNGKey(0), cfg)

    # uninterrupted run: 6 steps
    loop_a = make_loop(tmp_path / "a")
    params_a = loop_a.run(jax.tree_util.tree_map(jnp.copy, params0), 6)

    # interrupted run: 3 steps, then resume for 3 more
    loop_b1 = make_loop(tmp_path / "b")
    loop_b1.run(jax.tree_util.tree_map(jnp.copy, params0), 3)
    loop_b2 = make_loop(tmp_path / "b")
    state, start = loop_b2.resume(params0)
    assert start == 3
    params_b = loop_b2.run(state, 3, start)

    for a, b in zip(jax.tree_util.tree_leaves(params_a),
                    jax.tree_util.tree_leaves(params_b)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)


def test_training_loss_decreases_e2e():
    from repro.core import dlrm as dlrm_lib
    from repro.core import sharding as dsh
    from repro.data import make_recsys_batch

    cfg = get_dlrm("dlrm-rm2-small-unsharded").reduced()
    mesh = make_host_mesh()
    step = dsh.make_dlrm_train_step(cfg, mesh, ("data", "model"), lr=0.1)
    params = dlrm_lib.init_dlrm(jax.random.PRNGKey(0), cfg)
    params = dsh.shard_dlrm_params(params, cfg, mesh, ("data", "model"))
    losses = []
    opt = None
    for s in range(80):
        b = make_recsys_batch(cfg, s)
        params, opt, loss = step(params, opt, b["dense"], b["indices"], b["labels"])
        losses.append(float(loss))
    # compare windowed means: single-batch losses are noisy at batch 16
    assert np.mean(losses[-10:]) < np.mean(losses[:10]), \
        (losses[:3], losses[-3:])


def test_planner_place_tables_respects_capacity():
    from repro.core.planner import place_tables

    cfg = get_dlrm("dlrm-rm2-small-unsharded")
    freq = np.linspace(1.0, 40.0, cfg.num_tables)      # table 39 hottest
    table_bytes = cfg.rows_per_table * cfg.embed_dim * 2
    placements, fast_used, bulk_used = place_tables(
        cfg, freq, fast_capacity_bytes=3 * table_bytes,
        bulk_capacity_bytes=40 * table_bytes, n_chips=4)
    fast_ids = {p.table_id for p in placements if p.tier == "fast"}
    assert len(fast_ids) == 12                         # 3 per chip x 4 chips
    # hottest tables got the fast tier
    assert {39, 38, 37}.issubset(fast_ids)
    assert fast_used + bulk_used == 40 * table_bytes


def test_elastic_remesh_roundtrip():
    from jax.sharding import PartitionSpec as P
    from repro.runtime import remesh_tree

    mesh1 = make_host_mesh()
    tree = {"w": jnp.arange(16.0).reshape(4, 4), "b": jnp.ones(3)}
    specs = {"w": P("data"), "b": P()}
    out, report = remesh_tree(tree, specs, mesh1)
    assert report["resharded"] >= 1
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))
    # non-divisible dim falls back to replication, data preserved
    tree2 = {"w": jnp.ones((3, 3)), "b": jnp.ones(3)}
    out2, report2 = remesh_tree(tree2, specs, mesh1)
    np.testing.assert_array_equal(np.asarray(out2["w"]), np.asarray(tree2["w"]))


CLI_ENV = dict(os.environ, PYTHONPATH=os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))


@pytest.mark.parametrize("cmd", [
    [sys.executable, "-m", "repro.launch.train", "--workload", "dlrm",
     "--smoke", "--steps", "8"],
    [sys.executable, "-m", "repro.launch.serve", "--smoke", "--queries", "10",
     "--sla-ms", "5000"],
])
def test_cli_entrypoints(cmd):
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=600,
                       env=CLI_ENV)
    assert r.returncode == 0, (r.stdout[-1000:], r.stderr[-2000:])
