"""MoE block invariants: routing conservation, capacity dropping, expert
parallelism shape contract."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.layers import init_moe, moe_block


def moe_cfg(E=4, K=2, cap=64.0):
    return ModelConfig(name="t", family="moe", n_layers=2, d_model=32,
                       n_heads=4, n_kv_heads=4, d_ff=64, vocab_size=64,
                       moe=MoEConfig(num_experts=E, top_k=K,
                                     capacity_factor=cap))


def test_moe_matches_dense_expert_mixture():
    """With no drops, MoE output == Σ_k gate_k · expert_k(x) computed naively."""
    cfg = moe_cfg()
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, cfg.d_model))
    out = moe_block(p, x, cfg)

    # naive dense reference
    N = 2 * 6
    xt = x.reshape(N, -1)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    gate, idx = jax.lax.top_k(probs, cfg.moe.top_k)
    gate = gate / gate.sum(-1, keepdims=True)

    def expert(e, v):
        h = jax.nn.silu(v @ p["w_gate"][e]) * (v @ p["w_up"][e])
        return h @ p["w_down"][e]

    ref = jnp.zeros_like(xt)
    for i in range(N):
        acc = jnp.zeros((cfg.d_model,), x.dtype)
        for k in range(cfg.moe.top_k):
            acc += gate[i, k].astype(x.dtype) * expert(int(idx[i, k]), xt[i])
        ref = ref.at[i].set(acc)
    np.testing.assert_allclose(np.asarray(out.reshape(N, -1), np.float32),
                               np.asarray(ref, np.float32),
                               rtol=5e-3, atol=5e-3)


def test_moe_capacity_drops_tokens():
    """With capacity_factor -> small, overloaded experts drop tokens (output
    contribution becomes zero), and raising capacity removes the drops."""
    cfg_small = moe_cfg(E=2, K=1, cap=0.25)
    cfg_big = dataclasses.replace(
        cfg_small, moe=dataclasses.replace(cfg_small.moe, capacity_factor=64.0))
    p = init_moe(jax.random.PRNGKey(0), cfg_small)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, cfg_small.d_model))
    out_small = moe_block(p, x, cfg_small)
    out_big = moe_block(p, x, cfg_big)
    # some tokens zeroed under tight capacity
    norms_small = jnp.linalg.norm(out_small[0], axis=-1)
    norms_big = jnp.linalg.norm(out_big[0], axis=-1)
    assert float((norms_small == 0).sum()) > 0
    assert float((norms_big == 0).sum()) == 0


def test_moe_gates_normalized():
    """Output scale is invariant to router logit offsets (softmax+renorm)."""
    cfg = moe_cfg()
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, cfg.d_model))
    out1 = moe_block(p, x, cfg)
    p2 = dict(p, router=p["router"] + 3.0)     # uniform logit shift
    out2 = moe_block(p2, x, cfg)
    np.testing.assert_allclose(np.asarray(out1, np.float32),
                               np.asarray(out2, np.float32),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("E,K", [(4, 1), (8, 2)])
def test_moe_shapes(E, K):
    cfg = moe_cfg(E=E, K=K)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    assert p["w_gate"].shape == (E, cfg.d_model, cfg.d_ff)
    x = jnp.ones((2, 3, cfg.d_model))
    assert moe_block(p, x, cfg).shape == x.shape
