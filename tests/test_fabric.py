"""repro.fabric: cross-board sharded serving correctness.

The invariants the subsystem must hold:

  * the partitioner accounts every byte against board capacity, balances
    lookup load under skew, and refuses a model the fleet cannot hold;
  * the remote-row cache is LFU over remote tables only, detects drift,
    and re-elects from post-drift counts;
  * exchange accounting: cache-off meters every remote bag, a saturating
    cache drives the wire bytes to zero, and reassembly order is exact;
  * THE fabric equivalence invariant (subprocess, real sub-meshes): a
    k-board ShardedFleet returns bit-identical per-query outputs to a
    single board holding the full model — cache on and off, across a
    zipf_drift trace with live cache re-elections;
  * the cluster's cost accounting (board-seconds, SLA violations) and
    the monitor's injectable service multiplier behave;
  * the bench is registered in benchmarks/run.py.
"""
import dataclasses
import os
import sys

import numpy as np
import pytest

from repro.configs.registry import get_dlrm
from repro.traffic import make_scenario

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _cfg(**kw):
    return dataclasses.replace(
        get_dlrm("dlrm-rm2-small-unsharded").reduced(), batch_size=8, **kw)


# ---------------------------------------------------------------------------
# Partition (unit)
# ---------------------------------------------------------------------------
def test_partition_accounts_capacity_and_balances_load():
    from repro.fabric import fits_one_board, partition_tables

    cfg = _cfg()
    tbytes = cfg.rows_per_table * cfg.embed_dim * 2
    # Zipf-ish table popularity: 1/(t+1); the heaviest table holds ~37% of
    # the mass, so the best achievable balance is ~1.47x the fair share
    freq = np.array([1.0 / (t + 1) for t in range(cfg.num_tables)])
    pm = partition_tables(cfg, freq, 4, 4 * tbytes)
    assert len(pm.owner) == cfg.num_tables
    assert sorted(sum((pm.tables_of(b) for b in range(4)), ())) \
        == list(range(cfg.num_tables))
    assert all(b <= 4 * tbytes for b in pm.board_bytes)
    assert pm.total_bytes == cfg.embedding_bytes
    # hottest-first onto least-loaded: the top-4 tables land on 4 DISTINCT
    # boards, so no board carries 2 of the heavy hitters
    owners_of_hot = {pm.owner[t] for t in range(4)}
    assert len(owners_of_hot) == 4
    assert pm.load_balance() < 1.6       # near the skew-imposed floor
    assert "boards" in pm.summary()
    # determinism
    pm2 = partition_tables(cfg, freq, 4, 4 * tbytes)
    assert pm2 == pm
    # tight capacity still respects the budget even when it breaks balance
    tight = partition_tables(cfg, freq, 4, 2 * tbytes)
    assert all(b <= 2 * tbytes for b in tight.board_bytes)

    assert not fits_one_board(cfg, cfg.embedding_bytes - 1)
    assert fits_one_board(cfg, cfg.embedding_bytes)


def test_partition_rejects_what_the_fleet_cannot_hold():
    from repro.fabric import partition_tables

    cfg = _cfg()
    tbytes = cfg.rows_per_table * cfg.embed_dim * 2
    with pytest.raises(ValueError, match="does not fit the fleet"):
        partition_tables(cfg, np.ones(cfg.num_tables), 2,
                         (cfg.num_tables // 2 - 1) * tbytes)
    with pytest.raises(ValueError, match="n_boards"):
        partition_tables(cfg, np.ones(cfg.num_tables), 0, tbytes)
    with pytest.raises(ValueError, match="one entry per table"):
        partition_tables(cfg, np.ones(3), 2, tbytes)


# ---------------------------------------------------------------------------
# Remote-row cache (unit, deterministic)
# ---------------------------------------------------------------------------
def test_remote_row_cache_lfu_and_drift_refresh():
    from repro.core import tiered_embedding as te
    from repro.fabric import RemoteRowCache

    cfg = _cfg()
    remote = [0, 1, 2, 3]
    freq = te.measure_row_freq(cfg, alpha=1.2, seed=0, n_batches=8)
    cache = RemoteRowCache(cfg, remote, capacity_rows=64, window=8,
                           refresh_threshold=0.7, cooldown_queries=10)
    base = cache.warm(freq)
    assert 0.0 < base <= 1.0 and 0 < cache.cached_rows <= 64
    # stats are keyed by global (table, row) — granularity-agnostic since
    # the row-range refactor — and hit_mask never claims a local lookup
    assert cache._cached.shape == (cfg.num_tables, cfg.rows_per_table)
    assert not cache._cached[4:].any()   # only remote rows ever cached
    assert cache.remote_tables == (0, 1, 2, 3)
    every_row = np.broadcast_to(
        np.arange(cfg.rows_per_table)[None, None, :],
        (1, cfg.num_tables, cfg.rows_per_table)).astype(np.int32)
    hm = cache.hit_mask(every_row)
    assert not hm[:, 4:, :].any() and hm[:, :4, :].any()

    from repro.data import make_recsys_batch
    # in-distribution queries score near the baseline
    for step in range(8):
        idx = np.asarray(make_recsys_batch(cfg, step, 0, 1.2)["indices"])
        h = cache.observe(idx, float(step))
    assert cache.windowed_hit_ratio() > 0.6 * base
    assert not cache.refreshes

    # drift: rotate the row space -> erosion -> reset -> re-election
    drift = 0
    for step in range(8, 60):
        idx = np.asarray(make_recsys_batch(cfg, step, 0, 1.2)["indices"])
        idx = (idx + 53) % cfg.rows_per_table
        cache.observe(idx, float(step))
        if cache.maybe_refresh(float(step)):
            drift = step
    assert len(cache.refreshes) >= 1, "drift never triggered a re-election"
    # post-refresh the cache serves the ROTATED stream again
    post = [cache.observe(
        (np.asarray(make_recsys_batch(cfg, s, 0, 1.2)["indices"]) + 53)
        % cfg.rows_per_table, float(s)) for s in range(60, 70)]
    assert np.mean(post) > 0.6 * base, np.mean(post)


def test_remote_row_cache_disabled_never_hits():
    from repro.fabric import RemoteRowCache
    from repro.core import tiered_embedding as te

    cfg = _cfg()
    freq = te.measure_row_freq(cfg, alpha=1.2, seed=0, n_batches=4)
    off = RemoteRowCache(cfg, [0, 1], capacity_rows=0)
    off.warm(freq)
    idx = np.zeros((2, cfg.num_tables, cfg.lookups_per_table), np.int32)
    assert not off.hit_mask(idx).any()
    assert off.observe(idx, 0.0) == 0.0 or not off.enabled


# ---------------------------------------------------------------------------
# Exchange accounting (unit)
# ---------------------------------------------------------------------------
def test_exchange_accounting_and_reassembly():
    from repro.core import perf_model
    from repro.fabric import (FabricExchange, RemoteRowCache,
                              partition_tables)

    cfg = _cfg()
    pm = partition_tables(cfg, np.ones(cfg.num_tables), 2,
                          cfg.embedding_bytes)
    link = perf_model.fabric_link(1.0, 100.0)
    ex = FabricExchange(cfg, pm, link)
    # reassembly: concat(owner slices)[inv_perm] restores table order
    concat = np.concatenate([t for t in ex.tables_by_board])
    assert list(concat[ex.inv_perm]) == list(range(cfg.num_tables))

    B, T, L = 4, cfg.num_tables, cfg.lookups_per_table
    idx = np.zeros((B, T, L), np.int32)
    t0 = ex.account(0, idx, cache=None)
    n_remote_tables = sum(1 for o in pm.owner if o != 0)
    assert t0.remote_lookups == n_remote_tables * B * L
    assert t0.miss_rows == t0.remote_lookups and t0.cache_hits == 0
    assert t0.miss_bags == n_remote_tables * B
    assert t0.bytes_out == t0.miss_rows * 4
    assert t0.bytes_in == t0.miss_bags * cfg.embed_dim * 2
    assert t0.t_link_s > 2 * link.latency - 1e-12

    # a cache holding every accessed row drives the wire bytes to zero
    cache = RemoteRowCache(cfg, [t for t in range(T) if pm.owner[t] != 0],
                           capacity_rows=T * cfg.rows_per_table)
    freq = np.zeros((T, cfg.rows_per_table))
    freq[:, 0] = 1.0                          # row 0 hot everywhere
    cache.warm(freq)
    t1 = ex.account(0, idx, cache)
    assert t1.miss_rows == 0 and t1.bytes_total == 0.0
    assert t1.remote_hit_ratio == 1.0 and t1.t_link_s == 0.0
    # local-only view: board that owns everything it sees
    solo = partition_tables(cfg, np.ones(T), 1, cfg.embedding_bytes)
    ex1 = FabricExchange(cfg, solo, link)
    tl = ex1.account(0, idx)
    assert tl.remote_lookups == 0 and tl.bytes_total == 0.0


# ---------------------------------------------------------------------------
# Fleet runs (in-process, boards share the single CPU device)
# ---------------------------------------------------------------------------
def test_fleet_report_and_cache_transparency():
    from repro.fabric import ShardedFleet

    cfg = _cfg()
    events = make_scenario("stationary", alpha=1.05).events(
        10, qps=400.0, seed=1)
    fleet = ShardedFleet(cfg, n_boards=2, alpha=1.05, max_batch_queries=2)
    r = fleet.run(events, sla_ms=1e6, scenario="stationary")
    assert sorted(fleet.completed) == [e.qid for e in events]
    assert r.n_boards == 2 and r.n_queries == 10
    assert r.bytes_per_query > 0
    assert 0.0 < r.remote_lookup_fraction < 1.0
    assert 0.0 <= r.link_stall_share <= 1.0
    assert r.board_seconds == pytest.approx(2 * r.makespan_s)
    assert r.sla_violations == 0
    assert not r.fits_one_board          # default budget < total bytes
    assert "fabric" in r.summary() and "B/query" in r.summary()

    off = ShardedFleet(cfg, n_boards=2, alpha=1.05, max_batch_queries=2,
                       cache_enabled=False)
    r_off = off.run(events, sla_ms=1e6, scenario="stationary")
    assert r_off.bytes_per_query > r.bytes_per_query  # cache saves wire
    # no cache -> no hit trajectory (None, not a cold-looking 0.0)
    assert r_off.remote_hit_first is None and r_off.remote_hit_last is None
    assert r.remote_hit_first is not None
    for ev in events:                    # ...without touching the results
        np.testing.assert_array_equal(
            fleet.completed[ev.qid].probs, off.completed[ev.qid].probs,
            err_msg=f"qid={ev.qid}")


def test_engine_builds_sharded_fleet():
    """`Engine.sharded_fleet` is the declarative entry point: the fleet
    inherits the engine's (alpha, seed) stream for profiling/partition."""
    from repro.engine import Engine
    from repro.fabric import ShardedFleet

    cfg = _cfg()
    eng = Engine(cfg, alpha=1.05, seed=7)
    fleet = eng.sharded_fleet(n_boards=2, max_batch_queries=2)
    assert isinstance(fleet, ShardedFleet)
    assert fleet.alpha == 1.05 and fleet.seed == 7
    assert fleet.n_boards == 2
    events = make_scenario("stationary", alpha=1.05).events(
        4, qps=400.0, seed=7)
    r = fleet.run(events, sla_ms=1e6)
    assert r.n_queries == 4

    from repro.configs.registry import get_arch
    with pytest.raises(ValueError, match="DLRM-only"):
        Engine(get_arch("deepseek-7b").reduced()).sharded_fleet()


def test_fabric_equivalence_sharded_vs_full_board(subproc):
    """THE acceptance invariant: a k-board fleet on REAL sub-meshes (8
    virtual devices, 2-device boards) returns bit-identical per-query
    outputs to a single board holding the full model — with the remote
    cache on and off, across a zipf_drift trace whose rotations force
    live cache re-elections mid-run."""
    code = """
    import dataclasses
    import numpy as np
    from repro.configs.registry import get_dlrm
    from repro.fabric import ShardedFleet
    from repro.traffic import make_scenario

    cfg = dataclasses.replace(get_dlrm("dlrm-rm2-small-unsharded").reduced(),
                              batch_size=8)
    events = make_scenario("zipf_drift", alpha=1.2, rotate_every_s=0.02,
                           salt_stride=37).events(120, qps=2000.0, seed=3)
    assert len({e.perm_salt for e in events}) > 1   # the trace DOES drift

    # reference: ONE board holding every table (capacity = full model)
    ref = ShardedFleet(cfg, n_boards=1, devices_per_board=2, alpha=1.2,
                       board_capacity_bytes=cfg.embedding_bytes,
                       max_batch_queries=2)
    ref.run(events, sla_ms=1e6)

    for cache_on in (True, False):
        fleet = ShardedFleet(cfg, n_boards=4, devices_per_board=2,
                             alpha=1.2, max_batch_queries=2,
                             cache_enabled=cache_on, cache_window=6,
                             cache_refresh_threshold=0.7, cache_cooldown=6,
                             router="jsq")
        assert len({id(b.mesh) for b in fleet.boards}) == 4
        r = fleet.run(events, sla_ms=1e6, scenario="zipf_drift")
        if cache_on:
            assert r.cache_refreshes > 0, "drift never re-elected the cache"
        for ev in events:
            got = fleet.completed[ev.qid].probs
            want = ref.completed[ev.qid].probs
            assert np.array_equal(got, want), (
                f"qid={ev.qid} cache={cache_on} "
                f"max|d|={np.max(np.abs(got - want))}")
    print("FABRIC-EQ-OK")
    """
    proc = subproc(code, n_devices=8)
    assert proc.returncode == 0, proc.stderr
    assert "FABRIC-EQ-OK" in proc.stdout


# ---------------------------------------------------------------------------
# Registration + perf-model terms
# ---------------------------------------------------------------------------
def test_fabric_link_model_terms():
    from repro.core import perf_model
    from repro.core.collectives import Topology

    link = perf_model.fabric_link(2.0, 50.0)
    assert link.latency == pytest.approx(2e-6)
    assert link.bandwidth == pytest.approx(50e9)
    t = perf_model.fabric_exchange_time(1e6, 1e6, 4, link)
    assert t == pytest.approx(2 * 2e-6 + 2e6 / 50e9)
    assert perf_model.fabric_exchange_time(0, 0, 4, link) == 0.0
    assert perf_model.fabric_exchange_time(1e6, 0, 1, link) == 0.0
    ring = perf_model.fabric_link(2.0, 50.0, topology=Topology.RING)
    assert (perf_model.fabric_exchange_time(1e6, 1e6, 8, ring)
            > perf_model.fabric_exchange_time(1e6, 1e6, 8, link))

    cfg = _cfg()
    sys_ = dataclasses.replace(perf_model.recspeed_system(), n_chips=1)
    bounds = [perf_model.sharded_query_bound(
        cfg, sys_, 4, perf_model.fabric_link(lat, 100.0), 0.5).qps
        for lat in (0.5, 2.0, 10.0)]
    assert bounds[0] > bounds[1] > bounds[2]   # latency sensitivity


def test_bench_fabric_registered():
    if REPO not in sys.path:
        sys.path.insert(0, REPO)
    from benchmarks import run as bench_run

    assert "fabric" in {name for name, _ in bench_run.SECTIONS}


def test_partition_warns_at_plan_time_near_capacity():
    """Regression: the >95%-fill warning must fire from partition_rows at
    PLAN time, not only when someone later prints summary()."""
    import warnings

    from repro.fabric import partition_tables

    cfg = _cfg()
    tbytes = cfg.rows_per_table * cfg.embed_dim * 2
    freq = np.ones(cfg.num_tables)
    # 2 equal boards of 4 equal tables: capacity 2% above the exact fill
    # puts every board at ~98% — inside the 5%-of-overflow band
    per_board = (cfg.num_tables // 2) * tbytes
    with pytest.warns(RuntimeWarning, match="within 5% of overflow"):
        pm = partition_tables(cfg, freq, 2, int(per_board * 1.02))
    assert pm.overfull_message() is not None
    # the message also lands in summary() output
    assert "WARNING" in pm.summary()
    # generous capacity: plan time stays silent
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        pm2 = partition_tables(cfg, freq, 2, cfg.embedding_bytes)
    assert pm2.overfull_message() is None
